"""Low-overhead span recorder with Chrome trace-event export.

Usage::

    from repro.obsv import trace

    with trace.TRACE.span("client.train", args={"client": ci}):
        ...                      # or @trace.traced("client.train")

Spans are complete-events: name, category, thread id, start and
duration on the ``time.perf_counter`` clock, plus optional args merged
with the recorder's *context tags* (e.g. the current round, set once
per round by the worker instead of threading a round index through
every call site).  Events live in a bounded ring buffer — a long run
keeps the most recent window instead of growing without bound.

Disabled is the default and costs (almost) nothing: ``span()`` returns
a shared no-op context manager — one attribute check, zero allocation —
so instrumentation can stay in hot paths permanently.  Enable with
``TRACE.enable()`` or the ``REPRO_TRACE`` environment variable (any
non-empty value ≠ "0"), which is how the launch CLIs turn tracing on in
child processes.

Export is Chrome trace-event JSON (the Perfetto / ``chrome://tracing``
format): ``ph:"X"`` duration events with microsecond timestamps, plus
``process_name`` metadata so every process of a federated deployment
gets its own named track.  Cross-process merging —
:func:`merge_snapshots` — maps each scraped process to a deterministic
synthetic pid and applies the per-process monotonic-clock offset
measured at scrape time (``perf_counter`` origins differ per process,
so raw timestamps are only comparable after alignment).
"""

from __future__ import annotations

import collections
import functools
import json
import os
import threading
import time
from typing import Optional

_perf = time.perf_counter


class _NoopSpan:
    """Shared do-nothing context manager for the disabled path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NOOP_SPAN = _NoopSpan()


class _Span:
    __slots__ = ("_rec", "name", "cat", "args", "_t0")

    def __init__(self, rec: "TraceRecorder", name: str, cat: str,
                 args: Optional[dict]):
        self._rec = rec
        self.name = name
        self.cat = cat
        self.args = args

    def __enter__(self):
        self._t0 = _perf()
        return self

    def __exit__(self, *exc):
        t0 = self._t0
        rec = self._rec
        args = self.args
        if rec.context:
            args = {**rec.context, **(args or {})}
        rec.events.append((self.name, self.cat,
                           threading.get_ident(), t0, _perf() - t0, args))
        return False


#: default ring capacity: ~100 B/event → a few MB worst case.
DEFAULT_CAPACITY = 65536


class TraceRecorder:
    """One per process (module singleton :data:`TRACE`)."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY, *,
                 process: str | None = None):
        self.enabled = False
        self.events: collections.deque = collections.deque(maxlen=capacity)
        self.context: dict = {}          # tags merged into every span
        self.process = process or "proc"

    # -- switches ----------------------------------------------------------

    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def clear(self) -> None:
        self.events.clear()

    def set_process(self, label: str) -> None:
        self.process = str(label)

    def set_context(self, **tags) -> None:
        """Merge tags into every subsequent span's args (round index,
        worker id, …).  A value of ``None`` removes the tag."""
        for k, v in tags.items():
            if v is None:
                self.context.pop(k, None)
            else:
                self.context[k] = v

    # -- recording ---------------------------------------------------------

    def span(self, name: str, cat: str = "",
             args: Optional[dict] = None):
        """Context manager for one span.  Disabled ⇒ the shared no-op
        (zero allocation — which is why tags travel via the ``args``
        dict parameter rather than ``**kwargs``: no-kwarg calls must
        not build a dict either)."""
        if not self.enabled:
            return NOOP_SPAN
        return _Span(self, name, cat, args)

    def instant(self, name: str, cat: str = "",
                args: Optional[dict] = None) -> None:
        """Zero-duration marker event."""
        if not self.enabled:
            return
        if self.context:
            args = {**self.context, **(args or {})}
        self.events.append((name, cat, threading.get_ident(),
                            _perf(), 0.0, args))

    # -- export ------------------------------------------------------------

    def snapshot(self, clear: bool = False) -> dict:
        """JSON-able dump for the wire: raw ``perf_counter`` seconds
        (this process's clock — the scraper aligns), plus the identity
        and the clock reading the offset handshake needs."""
        events = [list(e) for e in self.events]
        if clear:
            self.events.clear()
        return {"process": self.process, "pid": os.getpid(),
                "t_mono": _perf(), "events": events}

    def chrome_events(self, *, offset_s: float = 0.0,
                      pid: int | None = None) -> list[dict]:
        """This recorder's events in Chrome trace-event form."""
        return _snapshot_to_chrome(self.snapshot(), offset_s=offset_s,
                                   pid=pid)

    def write_chrome_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"traceEvents": self.chrome_events(),
                       "displayTimeUnit": "ms"}, f)


def traced(name: str, cat: str = ""):
    """Decorator form of :meth:`TraceRecorder.span` on the global
    recorder; disabled overhead is one attribute check per call."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*a, **kw):
            if not TRACE.enabled:
                return fn(*a, **kw)
            # bounded: `name` is the decorator's literal argument, fixed
            # per decorated function  # repro-lint: disable=TL001
            with TRACE.span(name, cat):
                return fn(*a, **kw)
        return wrapper
    return deco


# -- cross-process merge ------------------------------------------------------

def _snapshot_to_chrome(snap: dict, *, offset_s: float = 0.0,
                        pid: int | None = None) -> list[dict]:
    """One process snapshot → Chrome events (no metadata row)."""
    pid = snap.get("pid", 0) if pid is None else pid
    out = []
    for name, cat, tid, t0, dur, args in snap.get("events", ()):
        ev = {"name": name, "ph": "X", "pid": pid, "tid": tid,
              "ts": (t0 + offset_s) * 1e6, "dur": dur * 1e6}
        if cat:
            ev["cat"] = cat
        if args:
            ev["args"] = args
        out.append(ev)
    return out


def merge_snapshots(snaps: list[dict],
                    offsets: Optional[list[float]] = None) -> dict:
    """Merge per-process trace snapshots into one Chrome trace.

    ``offsets[i]`` (seconds, added to process i's timestamps) aligns
    each process's private ``perf_counter`` clock onto the merger's —
    the scrape-time handshake in :mod:`repro.obsv.teleserve` measures
    them.  Each process gets a deterministic synthetic pid (its index;
    Chrome pids are just track keys), so merging the same snapshots
    twice yields byte-identical output even when the sources are
    threads of one OS process sharing a real pid."""
    if offsets is None:
        offsets = [0.0] * len(snaps)
    events: list[dict] = []
    for i, (snap, off) in enumerate(zip(snaps, offsets)):
        pid = i + 1
        label = snap.get("process", f"proc{i}")
        events.append({"name": "process_name", "ph": "M", "pid": pid,
                       "tid": 0,
                       "args": {"name": f"{label} "
                                        f"(pid {snap.get('pid', '?')})"}})
        events.extend(_snapshot_to_chrome(snap, offset_s=off, pid=pid))
    # stable deterministic order: metadata first, then by time/track
    events.sort(key=lambda e: (e["ph"] != "M", e.get("ts", 0.0),
                               e["pid"], e["tid"], e["name"]))
    return {"traceEvents": events, "displayTimeUnit": "ms"}


#: process-global recorder — what the wire telemetry opcodes expose.
TRACE = TraceRecorder(
    process=os.environ.get("REPRO_TRACE_PROCESS") or "proc")
if os.environ.get("REPRO_TRACE", "0") not in ("", "0"):
    TRACE.enable()


def get_recorder() -> TraceRecorder:
    return TRACE
