"""Growth runtime: schedule → overlay → partition, round by round.

``GrowthRuntime`` is the single object the trainer (and the fedsvc
eval harness) holds: it owns the merged graph view, the evolving
partition and the applied-epoch watermark, and turns "round ``r`` is
starting" into "apply events ``applied+1 .. epoch_for_round(r)``".
Every step is deterministic in ``(schedule, partition seed, restream
config)``, so independent worker processes advance identical replicas
without exchanging graph state — the coordinator only synchronizes
*when* they advance, not *what* they apply.
"""

from __future__ import annotations

import numpy as np

from repro.obsv.metrics import REGISTRY
from repro.obsv.trace import TRACE

from .delta import GraphOverlay
from .events import GrowthSchedule
from .restream import RestreamConfig, edge_cut_stream, repartition

_SEGMENTS = REGISTRY.gauge("dyngraph.segments")
_EDGE_CUT = REGISTRY.gauge("dyngraph.edge_cut")
_BOUNDARY = REGISTRY.counter("dyngraph.boundary_registrations")


class GrowthRuntime:
    """Applies a :class:`GrowthSchedule` to a base graph over rounds."""

    def __init__(self, schedule: GrowthSchedule, base_graph,
                 num_clients: int, *, method: str = "ldg",
                 passes: int = 0, seed: int = 0):
        self.schedule = schedule
        self.base = base_graph
        self.graph = base_graph        # overlay after the first event
        self.num_clients = int(num_clients)
        self.restream_cfg = RestreamConfig(method=method,
                                           passes=passes, seed=seed)
        self.part: np.ndarray | None = None
        self.applied_epoch = 0
        self._overlay: GraphOverlay | None = None

    def epoch_for_round(self, round_idx: int) -> int:
        return self.schedule.epoch_for_round(round_idx)

    def record_boundary(self, n: int) -> None:
        """New boundary vertices registered with the exchange."""
        _BOUNDARY.inc(int(n))

    def advance_to(self, epoch: int, part: np.ndarray = None) -> bool:
        """Apply every event up to ``epoch``; → True if the graph (and
        partition) changed.  ``part`` seeds the partition the first
        time the caller (who ran the initial static partitioning)
        hands it over."""
        if part is not None and self.part is None:
            self.part = np.asarray(part, dtype=np.int32).copy()
        target = min(max(int(epoch), 0), self.schedule.num_events)
        if target <= self.applied_epoch:
            return False
        if self._overlay is None:
            self._overlay = GraphOverlay(self.base)
            self.graph = self._overlay
        for e in range(self.applied_epoch + 1, target + 1):
            src, dst, nodes = self.schedule.event_batch(e)
            with TRACE.span("dyngraph.apply",
                            args={"epoch": e, "edges": len(src)}):
                self._overlay.apply(src, dst, nodes)
            if self.part is not None:
                with TRACE.span("dyngraph.restream",
                                args={"epoch": e,
                                      "passes": self.restream_cfg.passes}):
                    self.part = repartition(
                        self._overlay, self.part, self.num_clients,
                        self.restream_cfg)
                _EDGE_CUT.set(edge_cut_stream(self._overlay, self.part))
            _SEGMENTS.set(len(self._overlay.segments))
        self.applied_epoch = target
        return True
