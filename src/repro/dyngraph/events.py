"""Seeded, reproducible graph-growth schedules.

A schedule carves one RMAT stream (``graphstore/generators.rmat_chunks``)
into a base graph plus ``num_events`` growth batches by *vertex
frontier*: event ``e`` admits every edge whose larger endpoint falls in
``[frontier(e-1), frontier(e))``.  Because an edge's epoch depends only
on its endpoints, the split is independent of chunking and of how many
events have already been applied — any process replaying the same
``(scale, edge_factor, seed, schedule)`` tuple sees byte-identical
batches, which is what lets multi-process fed workers grow their local
views independently yet stay in lockstep.

Node data is generated per fixed-size vertex block from a child-seeded
rng (``(seed, 0x5EED, block)``), so the arrays for vertex ``v`` never
depend on how far the frontier has advanced — the rows an event
introduces are the same rows a from-scratch build of the full graph
would hold, making compaction bit-identity possible at all.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.graphstore.builder import build_csr_store
from repro.graphstore.generators import rmat_chunks

NODE_BLOCK = 1 << 12


@dataclasses.dataclass(frozen=True)
class GrowthSchedule:
    """Everything a growth run depends on — JSON-safe by design."""

    scale: int                      # final graph has 2**scale vertices
    edge_factor: int = 8
    seed: int = 0
    base_frac: float = 0.5          # fraction of vertices in the base
    num_events: int = 4
    start_round: int = 1            # round before which event 1 lands
    every_rounds: int = 1           # rounds between events
    num_classes: int = 16
    feat_dim: int = 32
    train_frac: float = 0.01
    feature_noise: float = 2.0

    # -- geometry ----------------------------------------------------------

    @property
    def num_vertices(self) -> int:
        return 1 << self.scale

    @property
    def base_vertices(self) -> int:
        v0 = int(self.num_vertices * self.base_frac)
        return min(max(v0, self.num_classes), self.num_vertices)

    def frontier(self, epoch: int) -> int:
        """Vertex count after ``epoch`` events (0 = base)."""
        e = min(max(int(epoch), 0), self.num_events)
        v0, v = self.base_vertices, self.num_vertices
        return v0 + (v - v0) * e // self.num_events

    def epoch_for_round(self, round_idx: int) -> int:
        """Events due strictly before training round ``round_idx``."""
        if round_idx < self.start_round:
            return 0
        due = (round_idx - self.start_round) // self.every_rounds + 1
        return min(due, self.num_events)

    # -- edge streams ------------------------------------------------------

    def _band_chunks(self, lo: int, hi: int):
        """Edges whose larger endpoint lies in ``[lo, hi)``."""
        for src, dst in rmat_chunks(self.scale, self.edge_factor,
                                    self.seed):
            m = np.maximum(src, dst)
            keep = (m >= lo) & (m < hi)
            if np.any(keep):
                yield src[keep], dst[keep]

    def base_chunks(self):
        return self._band_chunks(0, self.base_vertices)

    def full_chunks(self):
        return self._band_chunks(0, self.num_vertices)

    def event_edges(self, epoch: int) -> tuple[np.ndarray, np.ndarray]:
        """All edges of event ``epoch`` (1-based), concatenated."""
        lo, hi = self.frontier(epoch - 1), self.frontier(epoch)
        srcs, dsts = [], []
        for s, d in self._band_chunks(lo, hi):
            srcs.append(s)
            dsts.append(d)
        if not srcs:
            return np.zeros(0, np.int64), np.zeros(0, np.int64)
        return (np.concatenate(srcs).astype(np.int64),
                np.concatenate(dsts).astype(np.int64))

    # -- node data ---------------------------------------------------------

    def _proj(self) -> np.ndarray:
        rng = np.random.default_rng((self.seed, 0x5EED))
        return rng.standard_normal(
            (self.num_classes, self.feat_dim)).astype(np.float32)

    def node_rows(self, lo: int, hi: int) -> dict:
        """Arrays for vertex rows ``[lo, hi)`` — identical no matter
        which frontier (or process) asks for them."""
        proj = self._proj()
        labels = np.zeros(hi - lo, np.int32)
        feats = np.zeros((hi - lo, self.feat_dim), np.float32)
        mask = np.zeros(hi - lo, bool)
        b = lo // NODE_BLOCK
        while b * NODE_BLOCK < hi:
            rng = np.random.default_rng((self.seed, 0x5EED, b))
            lab_b = rng.integers(0, self.num_classes,
                                 NODE_BLOCK).astype(np.int32)
            noise = rng.standard_normal(
                (NODE_BLOCK, self.feat_dim)).astype(np.float32)
            mask_b = rng.random(NODE_BLOCK) < self.train_frac
            s = max(lo, b * NODE_BLOCK)
            e = min(hi, (b + 1) * NODE_BLOCK)
            off = b * NODE_BLOCK
            labels[s - lo:e - lo] = lab_b[s - off:e - off]
            feats[s - lo:e - lo] = (proj[lab_b[s - off:e - off]]
                                    + self.feature_noise
                                    * noise[s - off:e - off])
            mask[s - lo:e - lo] = mask_b[s - off:e - off]
            b += 1
        # every class is seeded with at least one training vertex
        if lo < self.num_classes:
            mask[:self.num_classes - lo] = True
        return {"features": feats, "labels": labels, "train_mask": mask}

    def event_batch(self, epoch: int
                    ) -> tuple[np.ndarray, np.ndarray, dict]:
        src, dst = self.event_edges(epoch)
        return src, dst, self.node_rows(self.frontier(epoch - 1),
                                        self.frontier(epoch))

    # -- store builders ----------------------------------------------------

    def _node_writer(self, v: int):
        import os

        def write(path: str) -> dict:
            rows = self.node_rows(0, v)
            np.save(os.path.join(path, "features.npy"), rows["features"])
            np.save(os.path.join(path, "labels.npy"), rows["labels"])
            np.save(os.path.join(path, "train_mask.npy"),
                    rows["train_mask"])
            return {"num_classes": int(self.num_classes)}

        return write

    def build_base(self, path: str, *, name: str = "dyn_base"):
        """Materialize the epoch-0 store the overlay grows from."""
        v0 = self.base_vertices
        return build_csr_store(
            self.base_chunks(), v0, path, symmetric=True, dedup=True,
            est_pairs=max(1, self.num_vertices * self.edge_factor),
            node_writer=self._node_writer(v0), name=name)

    def build_full(self, path: str, *, name: str = "dyn_full"):
        """From-scratch build of the fully-grown graph — the reference
        the compaction bit-identity test compares against."""
        v = self.num_vertices
        return build_csr_store(
            self.full_chunks(), v, path, symmetric=True, dedup=True,
            est_pairs=max(1, self.num_vertices * self.edge_factor),
            node_writer=self._node_writer(v), name=name)

    # -- config plumbing ---------------------------------------------------

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "GrowthSchedule":
        return cls(**d)
