"""Dynamic-graph plane: delta segments, growth schedules, restreaming.

See :mod:`repro.dyngraph.delta` (overlay + compaction),
:mod:`repro.dyngraph.events` (seeded growth schedules),
:mod:`repro.dyngraph.restream` (incremental re-partitioning) and
:mod:`repro.dyngraph.runtime` (the per-run growth driver).
"""

from .delta import DeltaLog, GraphOverlay, Segment, compact
from .events import GrowthSchedule
from .restream import RestreamConfig, admit, edge_cut_stream, \
    repartition, restream_pass
from .runtime import GrowthRuntime

__all__ = [
    "DeltaLog", "GraphOverlay", "Segment", "compact",
    "GrowthSchedule", "RestreamConfig", "admit", "edge_cut_stream",
    "repartition", "restream_pass", "GrowthRuntime",
]
