"""Incremental re-partitioning for grown graphs.

Two pieces, both chunk-vectorized in the style of
``graphstore/partition_stream.ldg_partition``:

* :func:`admit` — new vertices arrive in id order and are placed
  against the *current* per-part loads without touching existing
  assignments: LDG scoring (``|N(v) ∩ P_i| · (1 − |P_i|/cap)``) or
  Fennel's marginal cost (``|N(v) ∩ P_i| − α·γ·|P_i|^{γ−1}`` with the
  standard ``α = m·k^{γ−1}/n^γ``), seeded jitter for ties, ranked
  admission under the capacity bound and water-fill for the leftovers.

* :func:`restream_pass` — one warm pass over *all* assignments
  (Stanton's restreaming LDG): every vertex is re-scored against the
  loads frozen at chunk start and moved when another part strictly
  beats its current one under the capacity bound.  A pass only ever
  reduces the number of cut edges it can see, which is where the
  ≥15 % edge-cut recovery over admit-only placement comes from.

Everything is deterministic in ``(graph, part, config)`` — fed workers
in different processes admit identically and never exchange partition
state.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.graphs.partition import _water_fill, ranks_within
from repro.graphstore.partition_stream import iter_edge_chunks


@dataclasses.dataclass(frozen=True)
class RestreamConfig:
    method: str = "ldg"             # "ldg" | "fennel"
    passes: int = 0                 # warm restreaming passes per event
    slack: float = 1.05
    gamma: float = 1.5              # Fennel load exponent
    seed: int = 0
    chunk_vertices: int = 1 << 16


def _scores(counts: np.ndarray, sizes: np.ndarray, cap: int,
            cfg: RestreamConfig, alpha: float,
            jitter: np.ndarray) -> np.ndarray:
    if cfg.method == "fennel":
        penalty = alpha * cfg.gamma * np.power(
            np.maximum(sizes, 1).astype(np.float64), cfg.gamma - 1.0)
        return counts - penalty[None, :] + jitter[None, :]
    penalty = np.maximum(0.0, 1.0 - sizes / cap)
    return counts * penalty[None, :] + jitter[None, :]


def _fennel_alpha(g, k: int, cfg: RestreamConfig) -> float:
    n = max(1, int(g.num_vertices))
    m = max(1, int(g.num_edges))
    return m * float(k) ** (cfg.gamma - 1.0) / float(n) ** cfg.gamma


def admit(g, part: np.ndarray, k: int,
          cfg: RestreamConfig = RestreamConfig()) -> np.ndarray:
    """Extend ``part`` (over the first ``len(part)`` vertices of ``g``)
    to all of ``g``'s vertices; existing entries are never moved."""
    v_old, v_new = len(part), int(g.num_vertices)
    out = np.full(v_new, -1, dtype=np.int32)
    out[:v_old] = part
    if v_new == v_old:
        return out
    cap = int(np.ceil(v_new / k) * cfg.slack)
    sizes = np.bincount(part[part >= 0], minlength=k).astype(np.int64)
    jitter = np.random.default_rng(cfg.seed).random(k) * 1e-9
    alpha = _fennel_alpha(g, k, cfg)

    for lo in range(v_old, v_new, cfg.chunk_vertices):
        hi = min(lo + cfg.chunk_vertices, v_new)
        B = hi - lo
        ptr = np.asarray(g.indptr[lo: hi + 1]).astype(np.int64)
        e_src = np.asarray(g.indices[ptr[0]: ptr[-1]]).astype(np.int64)
        e_dst_local = np.repeat(np.arange(B, dtype=np.int64),
                                np.diff(ptr))
        src_part = out[e_src]
        known = src_part >= 0
        counts = np.bincount(
            e_dst_local[known] * k + src_part[known],
            minlength=B * k).reshape(B, k)
        scores = _scores(counts, sizes, cap, cfg, alpha, jitter)
        best = np.argmax(scores, axis=1)
        has_affinity = counts[np.arange(B), best] > 0

        idx = np.nonzero(has_affinity)[0]
        taken = np.zeros(B, dtype=bool)
        if len(idx):
            dest = best[idx]
            ok = ranks_within(dest) < np.maximum(0, cap - sizes)[dest]
            taken[idx[ok]] = True
        out[lo:hi][taken] = best[taken].astype(np.int32)
        sizes += np.bincount(best[taken], minlength=k)

        rest = np.nonzero(~taken)[0]
        if len(rest):
            fills = _water_fill(sizes, len(rest))
            recv = np.argsort(sizes, kind="stable")
            out[lo:hi][rest] = np.repeat(
                recv, fills[recv]).astype(np.int32)
            sizes += fills
    return out


def restream_pass(g, part: np.ndarray, k: int,
                  cfg: RestreamConfig = RestreamConfig()) -> np.ndarray:
    """One warm re-assignment pass over every vertex."""
    v = int(g.num_vertices)
    out = np.asarray(part, dtype=np.int32).copy()
    cap = int(np.ceil(v / k) * cfg.slack)
    sizes = np.bincount(out, minlength=k).astype(np.int64)
    jitter = np.random.default_rng(cfg.seed).random(k) * 1e-9
    alpha = _fennel_alpha(g, k, cfg)

    for lo in range(0, v, cfg.chunk_vertices):
        hi = min(lo + cfg.chunk_vertices, v)
        B = hi - lo
        ptr = np.asarray(g.indptr[lo: hi + 1]).astype(np.int64)
        e_src = np.asarray(g.indices[ptr[0]: ptr[-1]]).astype(np.int64)
        e_dst_local = np.repeat(np.arange(B, dtype=np.int64),
                                np.diff(ptr))
        counts = np.bincount(
            e_dst_local * k + out[e_src],
            minlength=B * k).reshape(B, k)
        scores = _scores(counts, sizes, cap, cfg, alpha, jitter)
        cur = out[lo:hi].astype(np.int64)
        best = np.argmax(scores, axis=1)
        # move only on a strict *affinity* gain: score gains alone are
        # dominated by the load penalty and make batched moves thrash
        ar = np.arange(B)
        want = (best != cur) & (counts[ar, best] > counts[ar, cur])

        idx = np.nonzero(want)[0]
        if len(idx):
            dest = best[idx]
            ok = ranks_within(dest) < np.maximum(0, cap - sizes)[dest]
            moved = idx[ok]
            sizes += np.bincount(best[moved], minlength=k)
            sizes -= np.bincount(cur[moved], minlength=k)
            out[lo:hi][moved] = best[moved].astype(np.int32)
    return out


def repartition(g, part: np.ndarray, k: int,
                cfg: RestreamConfig = RestreamConfig()) -> np.ndarray:
    """Admit new vertices, then run the configured warm passes."""
    out = admit(g, part, k, cfg)
    for _ in range(max(0, int(cfg.passes))):
        out = restream_pass(g, out, k, cfg)
    return out


def edge_cut_stream(g, part: np.ndarray,
                    chunk_edges: int = 1 << 21) -> int:
    """Chunked ``edge_cut`` that never materializes the merged edge
    array — works on stores and overlays alike."""
    part = np.asarray(part)
    cut = 0
    for lo, hi in iter_edge_chunks(g, chunk_edges):
        ptr = np.asarray(g.indptr[lo: hi + 1]).astype(np.int64)
        e_src = np.asarray(g.indices[ptr[0]: ptr[-1]]).astype(np.int64)
        e_dst = np.repeat(np.arange(lo, hi, dtype=np.int64),
                          np.diff(ptr))
        cut += int((part[e_src] != part[e_dst]).sum())
    return cut
