"""Append-side CSR delta segments over a frozen mmap ``GraphStore``.

A *segment* is one batch of graph growth: a CSR fragment holding the
new in-edges (rows cover the grown vertex range, row contents are
deduped against everything already visible) plus the node arrays for
the vertices the batch introduced.  ``GraphOverlay`` stacks the base
store and any number of segments behind the ``Graph`` accessor
protocol — ``indptr``/``indices``/``features``/``labels``/
``train_mask``/``neighbours``/``in_degree`` — so the streaming
partitioner, shard extraction and samplers see one merged graph
without the base ever being rewritten.

Rows in the merged view are the concatenation of per-layer runs
(base run first, then each segment's run, oldest first); runs are
disjoint by construction because ``apply`` dedups new pairs against
the current merged view, and the merged edge *set* is kept symmetric
and self-loop-free — the same canonical form ``builder.py`` emits.
That invariant is what lets :func:`compact` feed the merged entries
back through ``build_csr_store`` as already-directed pairs and still
land bit-identical to a from-scratch rebuild of the full edge stream.

``DeltaLog`` persists segments as plain ``.npy`` files plus a JSON
manifest next to the base store, so a grown graph survives a restart
without recompacting.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.graphstore.builder import build_csr_store
from repro.obsv.metrics import REGISTRY
from repro.obsv.trace import TRACE

_COMPACT_S = REGISTRY.histogram("dyngraph.compact_s")

_NODE_KEYS = ("features", "labels", "train_mask")


class Segment:
    """One growth batch: segment CSR + node arrays for new rows."""

    def __init__(self, indptr: np.ndarray, indices: np.ndarray,
                 v_lo: int, v_hi: int, nodes: dict):
        self.indptr = np.ascontiguousarray(indptr, np.int64)
        self.indices = np.ascontiguousarray(indices, np.int64)
        self.v_lo = int(v_lo)          # first vertex id this batch added
        self.v_hi = int(v_hi)          # one past the last (== its V)
        self.nodes = nodes             # features/labels/train_mask rows
        assert len(self.indptr) == self.v_hi + 1

    @property
    def num_edges(self) -> int:
        return int(len(self.indices))


class _MergedIndices:
    """Array-like view of the merged edge array.

    Maps flat edge positions (merged-CSR order) to values across the
    base and segment runs of each row.  Supports the three access
    shapes the graph plane uses: contiguous slices (streaming chunk
    reads), int64 fancy indexing (eval subgraph gather) and full
    materialization via ``__array__``.
    """

    def __init__(self, overlay: "GraphOverlay"):
        self._ov = overlay

    @property
    def shape(self):
        return (self._ov.num_edges,)

    @property
    def dtype(self):
        return np.dtype(np.int64)

    def __len__(self) -> int:
        return self._ov.num_edges

    def __array__(self, dtype=None, copy=None):
        out = self[np.arange(self._ov.num_edges, dtype=np.int64)]
        return out if dtype is None else out.astype(dtype)

    def __getitem__(self, key):
        if isinstance(key, slice):
            start, stop, step = key.indices(self._ov.num_edges)
            if step != 1:
                raise IndexError("merged indices support unit-step slices")
            key = np.arange(start, stop, dtype=np.int64)
        pos = np.asarray(key, dtype=np.int64)
        scalar = pos.ndim == 0
        pos = np.atleast_1d(pos)
        ov = self._ov
        rows = np.searchsorted(ov.indptr, pos, side="right") - 1
        rem = pos - ov.indptr[rows]
        out = np.empty(len(pos), dtype=np.int64)
        deg = ov._base_deg[rows]
        hit = rem < deg
        if np.any(hit):
            out[hit] = _gather_base(ov.base, rows[hit], rem[hit])
        rem = rem - deg
        for seg in ov.segments:
            # rows newer than this segment have zero degree in it
            clamped = np.minimum(rows, seg.v_hi - 1)
            deg = np.where(rows < seg.v_hi,
                           np.diff(seg.indptr)[clamped], 0)
            hit = (rem >= 0) & (rem < deg)
            if np.any(hit):
                r = rows[hit]
                out[hit] = seg.indices[seg.indptr[r] + rem[hit]]
            rem = rem - deg
        return out[0] if scalar else out


def _gather_base(base, rows: np.ndarray, rem: np.ndarray) -> np.ndarray:
    starts = np.asarray(base.indptr)[rows].astype(np.int64)
    return np.asarray(base.indices)[starts + rem].astype(np.int64)


class _StackedRows:
    """Row-stacked view over the base node array + per-segment rows."""

    def __init__(self, blocks: list, bounds: np.ndarray):
        self._blocks = blocks          # block b covers [bounds[b], bounds[b+1])
        self._bounds = bounds

    @property
    def shape(self):
        first = np.asarray(self._blocks[0])
        return (int(self._bounds[-1]),) + first.shape[1:]

    @property
    def dtype(self):
        return np.asarray(self._blocks[0]).dtype

    def __len__(self) -> int:
        return int(self._bounds[-1])

    def __array__(self, dtype=None, copy=None):
        out = np.concatenate([np.asarray(b) for b in self._blocks], axis=0)
        return out if dtype is None else out.astype(dtype)

    def __getitem__(self, key):
        n = len(self)
        if isinstance(key, slice):
            start, stop, step = key.indices(n)
            key = np.arange(start, stop, step, dtype=np.int64)
        idx = np.asarray(key)
        scalar = idx.ndim == 0
        idx = np.atleast_1d(idx).astype(np.int64)
        block = np.searchsorted(self._bounds, idx, side="right") - 1
        out = None
        for b, blk in enumerate(self._blocks):
            hit = block == b
            if not np.any(hit):
                continue
            rows = np.asarray(blk)[idx[hit] - int(self._bounds[b])]
            if out is None:
                out = np.empty((len(idx),) + rows.shape[1:],
                               dtype=rows.dtype)
            out[hit] = rows
        if out is None:
            out = np.empty((0,) + np.asarray(self._blocks[0]).shape[1:],
                           dtype=self.dtype)
        return out[0] if scalar else out


class GraphOverlay:
    """Base store + delta segments behind the ``Graph`` protocol.

    Quacks like a ``GraphStore`` (``is_store`` is set so shard
    extraction takes the streaming path); with no segments every
    accessor passes straight through to the base, which is what makes
    an empty growth schedule bit-identical to static training.
    """

    is_store = True

    def __init__(self, base, segments: list = ()):  # noqa: B006
        self.base = base
        self.segments: list[Segment] = list(segments)
        self._base_v = int(base.num_vertices)
        self._base_deg = np.zeros(0, np.int64)
        self._rebuild_indptr()

    # -- merged shape ------------------------------------------------------

    def _rebuild_indptr(self) -> None:
        v = self._base_v if not self.segments else self.segments[-1].v_hi
        base_ptr = np.asarray(self.base.indptr, dtype=np.int64)
        deg = np.zeros(v, np.int64)
        deg[:self._base_v] = np.diff(base_ptr)
        self._base_deg = deg.copy()
        for seg in self.segments:
            deg[:seg.v_hi] += np.diff(seg.indptr)
        self.indptr = np.concatenate(
            [np.zeros(1, np.int64), np.cumsum(deg)])
        self.num_vertices = v
        self.num_edges = int(self.indptr[-1])
        if self.segments:
            self.indices = _MergedIndices(self)
            self.features = _StackedRows(
                [self.base.features] + [s.nodes["features"]
                                        for s in self.segments
                                        if s.v_hi > s.v_lo],
                self._node_bounds())
            self.labels = _StackedRows(
                [self.base.labels] + [s.nodes["labels"]
                                      for s in self.segments
                                      if s.v_hi > s.v_lo],
                self._node_bounds())
            self.train_mask = _StackedRows(
                [self.base.train_mask] + [s.nodes["train_mask"]
                                          for s in self.segments
                                          if s.v_hi > s.v_lo],
                self._node_bounds())
        else:
            self.indices = self.base.indices
            self.features = self.base.features
            self.labels = self.base.labels
            self.train_mask = self.base.train_mask

    def _node_bounds(self) -> np.ndarray:
        cuts = [0, self._base_v]
        cuts += [s.v_hi for s in self.segments if s.v_hi > s.v_lo]
        return np.asarray(sorted(set(cuts)), dtype=np.int64)

    # -- Graph protocol ----------------------------------------------------

    @property
    def feat_dim(self) -> int:
        return self.base.feat_dim

    @property
    def num_classes(self) -> int:
        return self.base.num_classes

    def in_degree(self, u=None):
        deg = np.diff(self.indptr)
        return deg if u is None else deg[u]

    def neighbours(self, u: int) -> np.ndarray:
        rows, vals = self.gather_rows(np.asarray([u], np.int64))
        return vals

    def train_vertices(self) -> np.ndarray:
        return np.nonzero(np.asarray(self.train_mask))[0]

    def gather_rows(self, rows: np.ndarray
                    ) -> tuple[np.ndarray, np.ndarray]:
        """→ (row id per value, values) for the merged rows, in merged
        order — the bulk primitive behind dedup and ``neighbours``."""
        rows = np.asarray(rows, np.int64)
        counts = np.diff(self.indptr)[rows]
        starts = self.indptr[rows]
        pos = np.repeat(starts, counts) + _ranges(counts)
        rids = np.repeat(rows, counts)
        if self.segments:
            vals = self.indices[pos]
        else:
            vals = np.asarray(self.base.indices)[pos].astype(np.int64)
        return rids, vals

    # -- growth ------------------------------------------------------------

    def apply(self, src: np.ndarray, dst: np.ndarray,
              nodes: dict | None = None) -> Segment:
        """Apply one growth batch: ``nodes`` carries the arrays for the
        newly added vertex rows (may be empty), ``src``/``dst`` the new
        undirected edges (symmetrized, self-loops dropped, deduped
        against the current merged view)."""
        nodes = nodes or {k: _empty_like(self, k) for k in _NODE_KEYS}
        n_new = len(nodes["labels"])
        v_lo, v_hi = self.num_vertices, self.num_vertices + n_new
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        s2 = np.concatenate([src, dst])
        d2 = np.concatenate([dst, src])
        keep = s2 != d2
        s2, d2 = s2[keep], d2[keep]
        if len(s2) and (s2.max() >= v_hi or d2.max() >= v_hi):
            raise ValueError("edge endpoint beyond grown vertex range")
        key = np.unique(d2 * np.int64(v_hi) + s2)
        d2, s2 = key // v_hi, key % v_hi
        # dedup against rows that already exist in the merged view
        old = d2 < self.num_vertices
        if np.any(old):
            touched = np.unique(d2[old])
            rids, vals = self.gather_rows(touched)
            have = rids * np.int64(v_hi) + vals
            dup = np.isin(d2 * np.int64(v_hi) + s2, have)
            s2, d2 = s2[~dup], d2[~dup]
        indptr = np.zeros(v_hi + 1, np.int64)
        np.add.at(indptr, d2 + 1, 1)
        seg = Segment(np.cumsum(indptr), s2, v_lo, v_hi, nodes)
        self.segments.append(seg)
        self._rebuild_indptr()
        return seg


def _ranges(counts: np.ndarray) -> np.ndarray:
    """[0..c0), [0..c1), ... concatenated."""
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, np.int64)
    out = np.ones(total, np.int64)
    out[0] = 0
    # zero-count rows give duplicate (or trailing out-of-range)
    # boundary positions: accumulate, and drop the past-the-end ones
    ends = np.cumsum(counts)[:-1]
    keep = ends < total
    np.subtract.at(out, ends[keep], counts[:-1][keep])
    return np.cumsum(out)


def _empty_like(ov: GraphOverlay, key: str) -> np.ndarray:
    ref = np.asarray(getattr(ov.base, key)[:1])
    return np.zeros((0,) + ref.shape[1:], dtype=ref.dtype)


# -- persistence --------------------------------------------------------------

MANIFEST_NAME = "delta_manifest.json"


class DeltaLog:
    """Segment files + manifest living next to (or apart from) a base
    store — the durable form of an overlay for single-process runs and
    compaction tooling.  Multi-process workers regenerate segments from
    the seeded schedule instead of sharing files."""

    def __init__(self, path: str):
        self.path = path
        os.makedirs(path, exist_ok=True)

    def _manifest(self) -> list[dict]:
        p = os.path.join(self.path, MANIFEST_NAME)
        if not os.path.exists(p):
            return []
        with open(p) as f:
            return json.load(f)["segments"]

    def append(self, seg: Segment) -> None:
        rows = self._manifest()
        i = len(rows)
        np.save(os.path.join(self.path, f"seg{i}_indptr.npy"), seg.indptr)
        np.save(os.path.join(self.path, f"seg{i}_indices.npy"), seg.indices)
        for k in _NODE_KEYS:
            np.save(os.path.join(self.path, f"seg{i}_{k}.npy"),
                    np.asarray(seg.nodes[k]))
        rows.append({"v_lo": seg.v_lo, "v_hi": seg.v_hi,
                     "num_edges": seg.num_edges})
        tmp = os.path.join(self.path, MANIFEST_NAME + ".tmp")
        with open(tmp, "w") as f:
            json.dump({"segments": rows}, f)
        os.replace(tmp, os.path.join(self.path, MANIFEST_NAME))

    def load(self, base) -> GraphOverlay:
        ov = GraphOverlay(base)
        for i, row in enumerate(self._manifest()):
            nodes = {k: np.load(os.path.join(self.path, f"seg{i}_{k}.npy"))
                     for k in _NODE_KEYS}
            ov.segments.append(Segment(
                np.load(os.path.join(self.path, f"seg{i}_indptr.npy")),
                np.load(os.path.join(self.path, f"seg{i}_indices.npy")),
                row["v_lo"], row["v_hi"], nodes))
        ov._rebuild_indptr()
        return ov


# -- compaction ---------------------------------------------------------------

def compact(overlay: GraphOverlay, out_path: str, *,
            name: str = "store", chunk_edges: int = 1 << 21,
            row_chunk: int = 1 << 14):
    """Fold base + segments into a fresh store at ``out_path``.

    The merged view is already the canonical symmetric, self-loop-free,
    deduped edge set, so its entries stream through ``build_csr_store``
    as directed pairs (``symmetric=False``) — per-bucket sort/unique
    then canonicalizes to exactly the CSR a from-scratch symmetric
    rebuild of the raw edge stream produces, bit for bit, at half the
    spill I/O.
    """
    from repro.graphstore.partition_stream import iter_edge_chunks

    t0 = time.perf_counter()
    with TRACE.span("dyngraph.compact",
                    args={"segments": len(overlay.segments)}):
        def merged_chunks():
            for lo, hi in iter_edge_chunks(overlay, chunk_edges):
                ptr = overlay.indptr[lo: hi + 1]
                e_src = np.asarray(overlay.indices[ptr[0]: ptr[-1]])
                e_dst = np.repeat(np.arange(lo, hi, dtype=np.int64),
                                  np.diff(ptr))
                yield e_src, e_dst

        def node_writer(path: str) -> dict:
            from numpy.lib.format import open_memmap
            v = overlay.num_vertices
            np.save(os.path.join(path, "labels.npy"),
                    np.asarray(overlay.labels))
            np.save(os.path.join(path, "train_mask.npy"),
                    np.asarray(overlay.train_mask))
            feats = open_memmap(
                os.path.join(path, "features.npy"), mode="w+",
                dtype=np.asarray(overlay.features[:1]).dtype,
                shape=(v, overlay.feat_dim))
            for lo in range(0, v, row_chunk):
                hi = min(lo + row_chunk, v)
                feats[lo:hi] = overlay.features[lo:hi]
            feats.flush()
            del feats
            return {"num_classes": int(overlay.num_classes)}

        store = build_csr_store(
            merged_chunks(), overlay.num_vertices, out_path,
            symmetric=False, dedup=True,
            est_pairs=max(1, overlay.num_edges),
            node_writer=node_writer, name=name,
            meta_extra={"compacted_segments": len(overlay.segments)})
    _COMPACT_S.observe(time.perf_counter() - t0)
    return store
