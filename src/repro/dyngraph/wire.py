"""Growth-control wire format, on repro.exchange.wire framing.

One opcode: ``OP_GROWTH`` carries a worker's growth-epoch barrier
report to the coordinator as a JSON header (mirroring the fedsvc
body layout — ``u8 op | u32 len | JSON`` — so the two planes stay
byte-compatible on the same socket)::

    OP_GROWTH  request:  u8 op | u32 header length | UTF-8 JSON header
               response: ok (empty payload)

The header is ``{"worker_id", "round", "epoch", "num_vertices",
"num_edges"}``: the worker has applied every delta up to ``epoch`` and
its merged view has the given shape.  The coordinator blocks the reply
until every active worker reports the same epoch, so no worker trains
round ``r`` against a graph another worker has not yet grown to.

Opcodes 48–63 belong to this plane; repro-lint (family WP) verifies the
payload layout against the parser and the pinned registry in
:mod:`repro.analysis.rules_wire`.
"""

from __future__ import annotations

import json
import struct

from repro.exchange.wire import (  # noqa: F401  (re-exported for callers)
    build_err, build_ok, parse_response, recv_frame, send_frame,
)

OP_GROWTH = 48

#: numeric band reserved for growth-control opcodes (48..63); servers
#: route any opcode in the band here without naming individual ops.
GROWTH_LO = 48
GROWTH_HI = 63

_U8 = struct.Struct("<B")
_U32 = struct.Struct("<I")


def build_growth(header: dict) -> bytes:
    blob = json.dumps(header, separators=(",", ":")).encode("utf-8")
    return _U8.pack(OP_GROWTH) + _U32.pack(len(blob)) + blob


def parse_growth_request(body) -> tuple[int, dict]:
    view = memoryview(body)
    (op,) = _U8.unpack_from(view, 0)
    if op == OP_GROWTH:
        (hlen,) = _U32.unpack_from(view, 1)
        off = 1 + _U32.size
        header = json.loads(bytes(view[off:off + hlen]).decode("utf-8"))
        return op, header
    raise ValueError(f"unknown growth opcode {op}")


def growth_rpc(sock, header: dict) -> None:
    """Send one growth barrier report and block on the reply."""
    send_frame(sock, build_growth(header))
    resp = recv_frame(sock)
    if resp is None:
        raise ConnectionError("coordinator closed connection")
    parse_response(resp)
