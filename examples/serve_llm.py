"""Batched serving example over the architecture zoo.

Serves three different families (GQA dense, SSM, MLA+MoE) with batched
requests through the same decode path the dry-run lowers for decode_32k,
and prints tokens/s.

Run:  PYTHONPATH=src python examples/serve_llm.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.data import synthetic_request_stream
from repro.models import lm


def serve(arch, batch=4, prompt=16, generate=16):
    cfg = get_reduced(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    cache = lm.init_cache(cfg, batch, prompt + generate)
    dec = jax.jit(lambda p, t, c: lm.decode_step(p, cfg, t, c))
    prompts = next(synthetic_request_stream(cfg, batch=batch,
                                            prompt_len=prompt, seed=0))
    toks = jnp.asarray(prompts[:, :1], jnp.int32)
    logits = None
    t0 = time.perf_counter()
    for step in range(prompt + generate - 1):
        logits, cache = dec(params, toks, cache)
        toks = jnp.asarray(prompts[:, step + 1: step + 2], jnp.int32) \
            if step < prompt - 1 else jnp.argmax(logits, -1).astype(jnp.int32)
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    n = batch * (prompt + generate - 1)
    print(f"  {arch:24s} ({cfg.family:6s}) {n / dt:7.1f} tok/s")


def main():
    print("batched serving across families (CPU, reduced configs):")
    for arch in ("smollm-360m", "mamba2-1.3b", "deepseek-v2-lite-16b"):
        serve(arch)


if __name__ == "__main__":
    main()
