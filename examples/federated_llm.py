"""The paper's systems ideas on the transformer zoo (DESIGN.md §3):
cross-silo federated training with delta pruning + stale aggregation.

Two silos train a reduced smollm on disjoint synthetic shards; we compare
  dense  — FedAvg every round (EmbC analogue: ship everything)
  pruned — top-10% magnitude delta sparsification (§4.1 analogue)
  stale  — pruned + one-round-stale aggregation (§4.2 overlap analogue)
and report loss + bytes shipped per round.

Run:  PYTHONPATH=src python examples/federated_llm.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.core.fedopt import FedOptConfig, FederatedLMTrainer
from repro.data import synthetic_batches
from repro.optim import adamw


def stack_silo_batches(cfg, num_silos, local_steps, batch, seq, seed):
    gens = [synthetic_batches(cfg, batch=batch, seq=seq, seed=seed + 31 * s)
            for s in range(num_silos)]

    while True:
        per_silo = []
        for g in gens:
            steps = [next(g) for _ in range(local_steps)]
            per_silo.append(jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *steps))
        yield jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_silo)


def run(name, fed_cfg, rounds=6):
    cfg = get_reduced("smollm-360m")
    tr = FederatedLMTrainer(cfg, adamw(2e-3), fed_cfg)
    gen = stack_silo_batches(cfg, fed_cfg.num_silos, fed_cfg.local_steps,
                             batch=2, seq=32, seed=0)
    losses = []
    for r in range(rounds):
        m = tr.round(next(gen))
        losses.append(m["loss"])
    mb = tr.comm_bytes_per_round() / 2**20
    print(f"{name:7s} loss {losses[0]:.3f} -> {losses[-1]:.3f}   "
          f"~{mb:.2f} MiB shipped/round (x{fed_cfg.num_silos} silos)")
    return losses


def main():
    print("federated LLM training, 2 silos x 4 local steps:")
    run("dense", FedOptConfig(num_silos=2, local_steps=4))
    run("pruned", FedOptConfig(num_silos=2, local_steps=4,
                               delta_topk_frac=0.10))
    run("stale", FedOptConfig(num_silos=2, local_steps=4,
                              delta_topk_frac=0.10, stale_aggregation=True))
    print("\npruned ships ~10% of the bytes; stale hides the aggregation "
          "behind the next round's compute (one-round staleness, §4.2).")


if __name__ == "__main__":
    main()
