"""End-to-end driver (deliverable b): full federated GNN training session.

The paper's workload, end to end: a Products-like graph partitioned onto
4 clients, pre-training bootstrap, 30 federated rounds of 3 local epochs
under the best OptimES strategy (OPG), with per-round accuracy/timing
logs, a final TTA report against the EmbC baseline, and (measured
compute + modelled 1 Gbps network) phase breakdowns.

Run:  PYTHONPATH=src python examples/train_federated_e2e.py [--rounds N]
"""

import argparse

import numpy as np

from repro.core import default_strategies, FederatedGNNTrainer, \
    peak_accuracy, time_to_accuracy
from repro.graphs import make_graph


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--conv", choices=("graphconv", "sageconv"),
                    default="graphconv")
    args = ap.parse_args()

    graph = make_graph("products", scale=0.4, seed=1)
    print(f"graph: V={graph.num_vertices} E={graph.num_edges} "
          f"avg_deg={graph.avg_degree():.1f}")

    strategies = default_strategies()
    runs = {}
    for name in ("E", "OPG"):
        print(f"\n=== strategy {name}: {strategies[name].describe()} ===")
        tr = FederatedGNNTrainer(graph, args.clients, strategies[name],
                                 conv=args.conv, batch_size=256, seed=0)
        stats = tr.train(args.rounds, verbose=True)
        runs[name] = stats

    target = min(peak_accuracy(s) for s in runs.values()) - 0.01
    print(f"\n=== summary (target acc {target:.4f}) ===")
    for name, stats in runs.items():
        t = time_to_accuracy(stats, target)
        rt = float(np.median([s.round_time for s in stats]))
        print(f"{name:4s} peak={peak_accuracy(stats):.4f} "
              f"median_round={rt:.2f}s "
              f"TTA={t if t is not None else float('nan'):.1f}s")
    e, o = runs["E"], runs["OPG"]
    te, to = time_to_accuracy(e, target), time_to_accuracy(o, target)
    if te and to:
        print(f"\nOptimES(OPG) reaches target {te / to:.2f}x faster than "
              f"EmbC — the paper reports ≈3.6x for Products (Fig. 6b).")


if __name__ == "__main__":
    main()
