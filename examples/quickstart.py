"""Quickstart: the paper's headline comparison in ~2 minutes on CPU.

Trains a federated GNN on a dense synthetic (Reddit-like) graph with
cross-client edges under three regimes and prints the Fig. 6a story:

  D    default federated GNN (no embedding exchange)  — fast, low accuracy
  E    EmbC (pull/push all boundary embeddings)       — accurate, slow
  OPP  OptimES (prune + overlap + scored prefetch)    — accurate AND fast

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import default_strategies, FederatedGNNTrainer, \
    peak_accuracy, time_to_accuracy
from repro.graphs import make_graph


def main():
    graph = make_graph("reddit", scale=0.3, seed=3)
    print(f"graph: V={graph.num_vertices} E={graph.num_edges} "
          f"avg_deg={graph.avg_degree():.0f} classes={graph.num_classes}")
    rounds = 10
    results = {}
    for name in ("D", "E", "OPP"):
        strat = default_strategies()[name]
        tr = FederatedGNNTrainer(graph, 4, strat, batch_size=128, seed=0)
        stats = tr.train(rounds, verbose=False)
        results[name] = stats
        print(f"  trained {name:3s}: {strat.describe()}")

    target = min(peak_accuracy(s) for n, s in results.items()
                 if n != "D") - 0.01
    print(f"\n{'strategy':10s} {'peak acc':>9s} {'median round':>13s} "
          f"{'TTA(@{:.0%})'.format(target):>12s} {'emb stored':>11s}")
    for name, stats in results.items():
        rt = float(np.median([s.round_time for s in stats]))
        t = time_to_accuracy(stats, target, smooth=3)
        print(f"{name:10s} {peak_accuracy(stats):9.4f} {rt:12.3f}s "
              f"{t if t is not None else float('nan'):11.2f}s "
              f"{stats[-1].embeddings_stored:11d}")
    print("\nExpected ordering (paper Fig. 6a): accuracy D < E ≈ OPP; "
          "round time OPP < E.")


if __name__ == "__main__":
    main()
