"""Fig. 10: retention-limit (P_i) ablation with uniform random pruning —
per-round time, peak accuracy, embeddings stored at the server."""

from __future__ import annotations

import dataclasses

from repro.core import Strategy

from .common import FULL, QUICK, emit, graph_for, quick_mode, run_strategy, \
    summarize

LIMITS = (0, 2, 4, 8, None)   # P_0 (=D) … P_inf (=EmbC)


def main():
    mode = QUICK if quick_mode() else FULL
    for gname in mode["graphs"]:
        g, bs = graph_for(gname)
        for limit in LIMITS:
            if limit == 0:
                strat = Strategy(f"P_0", use_embeddings=False)
            else:
                strat = Strategy(f"P_{limit}", retention_limit=limit)
            _, stats = run_strategy(g, bs, strat, rounds=mode["rounds"])
            s = summarize(stats)
            tag = "inf" if limit is None else limit
            emit(f"retention/{gname}/P_{tag}", s,
                 f"peak={s['peak_acc']:.4f};stored={s['stored']};"
                 f"pull={s['pull']:.3f};push={s['push']:.3f}")


if __name__ == "__main__":
    main()
