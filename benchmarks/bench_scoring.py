"""Fig. 11: scored-pruning ablation on the dense graph — EmbC baseline,
random-25% (R25), top-f% frequency (T5..T75), bridge/degree centrality
(B25/D25); peak accuracy + TTA."""

from __future__ import annotations

import dataclasses

from repro.core import Strategy, default_strategies, peak_accuracy

from .common import QUICK, FULL, emit, graph_for, quick_mode, \
    run_strategy, target_margin, \
    summarize, tta


def variants():
    base = dict(overlap_push=True, retention_limit=4)
    out = {"E": Strategy("E")}
    out["R25"] = Strategy("OPG_R25", scored_prune_frac=0.25,
                          random_subset=True, **base)
    for f in (5, 25, 50, 75):
        out[f"T{f}"] = Strategy(f"OPG_T{f}", scored_prune_frac=f / 100,
                                **base)
    out["B25"] = Strategy("OPG_B25", scored_prune_frac=0.25,
                          score_kind="bridge", **base)
    out["D25"] = Strategy("OPG_D25", scored_prune_frac=0.25,
                          score_kind="degree", **base)
    return out


def main():
    mode = QUICK if quick_mode() else FULL
    convs = ("graphconv",) if quick_mode() else ("graphconv", "sageconv")
    g, bs = graph_for("reddit")
    for conv in convs:
        results = {}
        for name, strat in variants().items():
            _, stats = run_strategy(g, bs, strat, rounds=mode["rounds"],
                                    conv=conv)
            results[name] = stats
        target = min(peak_accuracy(s) for s in results.values()) - target_margin()
        for name, stats in results.items():
            s = summarize(stats)
            emit(f"scoring/{conv}/reddit/{name}", s,
                 f"peak={s['peak_acc']:.4f};tta_s={tta(stats, target):.2f}")


if __name__ == "__main__":
    main()
