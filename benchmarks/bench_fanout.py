"""Fig. 14: effect of sampler fanout (5/10/15) on TTA and peak accuracy."""

from __future__ import annotations

from repro.core import default_strategies, peak_accuracy

from .common import QUICK, FULL, emit, graph_for, quick_mode, \
    run_strategy, target_margin, \
    summarize, tta

FANOUTS = (5, 10, 15)
STRATS = ("E", "OP", "OPP", "OPG")


def main():
    mode = QUICK if quick_mode() else FULL
    g, bs = graph_for("reddit")
    for fanout in FANOUTS:
        results = {}
        for sname in STRATS:
            strat = default_strategies()[sname]
            _, stats = run_strategy(g, bs, strat, fanout=fanout,
                                    rounds=mode["rounds"])
            results[sname] = stats
        target = min(peak_accuracy(s) for s in results.values()) - target_margin()
        for sname, stats in results.items():
            s = summarize(stats)
            emit(f"fanout/reddit/f{fanout}/{sname}", s,
                 f"peak={s['peak_acc']:.4f};tta_s={tta(stats, target):.2f}")


if __name__ == "__main__":
    main()
