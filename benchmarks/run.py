"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Default is quick mode (small
round counts, 2 graphs); pass ``--full`` for the paper-scale sweep used
in EXPERIMENTS.md.  The roofline section reads results/dryrun.json — run
``python -m repro.launch.dryrun --all`` first for fresh numbers.
"""

from __future__ import annotations

from . import (bench_exchange, bench_fanout, bench_fedopt, bench_gnnserve,
               bench_pull, bench_retention, bench_round_time, bench_scaling,
               bench_scoring, bench_tta, roofline)


def main() -> None:
    print("name,us_per_call,derived")
    for mod, tag in (
        (bench_tta, "Fig6/8 TTA+peak+convergence"),
        (bench_round_time, "Fig7 round-time components"),
        (bench_retention, "Fig10 retention ablation"),
        (bench_scoring, "Fig11 scoring ablation"),
        (bench_pull, "Fig12 pull prefetch analysis"),
        (bench_scaling, "Fig13 client scaling"),
        (bench_fanout, "Fig14 fanout"),
        (bench_exchange, "Beyond-paper: exchange codec x delta x shards"),
        (bench_fedopt, "Beyond-paper: federated LLM delta pruning/overlap"),
        (bench_gnnserve, "Beyond-paper: serving plane open-loop latency"),
        (roofline, "Roofline (deliverable g)"),
    ):
        print(f"# --- {tag} ---", flush=True)
        mod.main()


if __name__ == "__main__":
    main()
