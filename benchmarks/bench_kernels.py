"""Fused exchange-kernel sweep: rooflines + measured fused-vs-unfused.

For each fused device kernel of the exchange plane —

  gather_quantize   — pull response: row gather fused with int8 encode
  dequant_scatter   — push apply: int8 decode fused with table scatter
  dequant_aggregate — pulled int8 rows fed straight to ELL mean-agg

— this sweep reports:

  1. **Analytic roofline terms** on the TPU constants of
     ``repro.launch.mesh`` (the same term model as
     ``benchmarks/roofline.py``): compute term = FLOPs / peak,
     memory term = HBM bytes / HBM bandwidth, plus the HBM bytes the
     *unfused* pipeline would move (the fp32 intermediate written and
     re-read between the two passes).  All three kernels are firmly
     memory-bound, so the fused/unfused HBM ratio is the expected TPU
     speedup.
  2. **Measured wall-clock** on this CPU container with interpret off —
     the numpy-vs-device *dispatch* comparison: the fused path runs the
     jitted device program on device-resident tables (what
     ``device_tables=True`` servers execute), the unfused baseline runs
     the numpy host pipeline plus the host↔device staging the old plane
     paid (fp32 crosses the boundary instead of int8).
  3. **Exchange-plane bytes/s**: the wire-form bytes each kernel
     produces/consumes per second, against the NetworkModel bandwidth
     *fitted* from live loopback RPCs (``fit_network_model`` over
     TcpTransport samples) — showing the codec kernels clear the wire
     with margin, i.e. compression stays off the critical path.

Persists ``BENCH_kernels.json`` at the repo root and prints the usual
``name,us_per_call,derived`` CSV rows.  ``--full`` widens the sweep.
"""

from __future__ import annotations

import json
import pathlib
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import NetworkModel, fit_network_model
from repro.kernels import ops, ref
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def _median_s(fn, *, reps: int = 20, warmup: int = 3) -> float:
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _terms(flops: float, hbm_bytes: float) -> dict:
    ct = flops / PEAK_FLOPS_BF16
    mt = hbm_bytes / HBM_BW
    return {"compute_s": ct, "memory_s": mt,
            "dominant": "memory" if mt >= ct else "compute"}


# -- per-kernel cases ---------------------------------------------------------

def case_gather_quantize(R: int, n: int, h: int, rng) -> dict:
    table = rng.normal(size=(R, h)).astype(np.float32)
    rows = rng.choice(R, size=n, replace=False)
    tbl_dev = jnp.asarray(table)
    jax.block_until_ready(tbl_dev)

    def fused():
        v, s = ops.gather_quantize(tbl_dev, rows)
        jax.block_until_ready((v, s))

    def unfused():
        # the numpy plane: host gather, host encode, fp32-era staging of
        # the wire arrays onto the device for the downstream consumer
        v, s = ops._np_gather_quantize(table, rows)
        jax.block_until_ready((jnp.asarray(v), jnp.asarray(s)))

    wire_bytes = n * h + 4 * n                       # int8 rows + scales
    fused_hbm = n * h * 4 + 4 * n + wire_bytes       # read rows, write wire
    unfused_hbm = fused_hbm + 2 * n * h * 4          # + fp32 block w+r
    return {
        "name": "gather_quantize", "shape": {"R": R, "n": n, "hidden": h},
        "roofline": {**_terms(4.0 * n * h, fused_hbm),
                     "hbm_bytes_fused": fused_hbm,
                     "hbm_bytes_unfused": unfused_hbm,
                     "hbm_savings_x": unfused_hbm / fused_hbm},
        "fused_s": _median_s(fused), "unfused_s": _median_s(unfused),
        "wire_bytes": wire_bytes,
    }


def case_dequant_scatter(R: int, n: int, h: int, rng) -> dict:
    table = rng.normal(size=(R, h)).astype(np.float32)
    rows = rng.choice(R, size=n, replace=False)
    values, scales = ops._np_quantize_int8(
        rng.normal(size=(n, h)).astype(np.float32))
    tbl_dev = jnp.asarray(table)
    jax.block_until_ready(tbl_dev)

    def fused():
        # wire form (host) → one fused decode+scatter into the resident
        # table; int8 crosses the boundary
        jax.block_until_ready(ops.dequant_scatter(tbl_dev, rows,
                                                  values, scales))

    rows_dev = jnp.asarray(rows)

    @jax.jit
    def _scatter(t, idx, new):
        return t.at[idx].set(new)

    def unfused():
        # host decode first: the fp32 rows cross the boundary (4×), then
        # a separate device scatter
        new = ops._np_dequantize_int8(values, scales)
        jax.block_until_ready(_scatter(tbl_dev, rows_dev, jnp.asarray(new)))

    wire_bytes = n * h + 4 * n
    fused_hbm = wire_bytes + n * h * 4               # read wire, write rows
    unfused_hbm = fused_hbm + 2 * n * h * 4          # + fp32 block w+r
    return {
        "name": "dequant_scatter", "shape": {"R": R, "n": n, "hidden": h},
        "roofline": {**_terms(1.0 * n * h, fused_hbm),
                     "hbm_bytes_fused": fused_hbm,
                     "hbm_bytes_unfused": unfused_hbm,
                     "hbm_savings_x": unfused_hbm / fused_hbm},
        "fused_s": _median_s(fused), "unfused_s": _median_s(unfused),
        "wire_bytes": wire_bytes,
    }


def case_dequant_aggregate(n_src: int, n_dst: int, k: int, h: int,
                           rng) -> dict:
    values, scales = ops._np_quantize_int8(
        rng.normal(size=(n_src, h)).astype(np.float32))
    ell_idx = rng.integers(0, n_src, size=(n_dst, k)).astype(np.int32)
    ell_mask = rng.random((n_dst, k)) < 0.85
    idx_dev, mask_dev = jnp.asarray(ell_idx), jnp.asarray(ell_mask)
    fused_fn = jax.jit(ref.dequant_aggregate)
    agg_fn = jax.jit(ref.gnn_aggregate)
    jax.block_until_ready((idx_dev, mask_dev))

    def fused():
        # pulled wire form crosses at 1 B/scalar; dequant fuses into the
        # aggregation gather — the fp32 source table never materializes
        jax.block_until_ready(fused_fn(jnp.asarray(values),
                                       jnp.asarray(scales),
                                       idx_dev, mask_dev))

    def unfused():
        # host dequant materializes the fp32 table, which then crosses
        # the boundary at 4 B/scalar before a separate aggregation
        feats = ops._np_dequantize_int8(values, scales)
        jax.block_until_ready(agg_fn(jnp.asarray(feats), idx_dev, mask_dev))

    wire_bytes = n_src * h + 4 * n_src
    fused_hbm = wire_bytes + n_dst * h * 4
    unfused_hbm = fused_hbm + 2 * n_src * h * 4      # fp32 table w+r
    return {
        "name": "dequant_aggregate",
        "shape": {"n_src": n_src, "n_dst": n_dst, "K": k, "hidden": h},
        "roofline": {**_terms(2.0 * n_dst * k * h, fused_hbm),
                     "hbm_bytes_fused": fused_hbm,
                     "hbm_bytes_unfused": unfused_hbm,
                     "hbm_savings_x": unfused_hbm / fused_hbm},
        "fused_s": _median_s(fused), "unfused_s": _median_s(unfused),
        "wire_bytes": wire_bytes,
    }


# -- fitted wire bandwidth ----------------------------------------------------

def fitted_bandwidth(hidden_sweep, n_sweep) -> float:
    """Fit the NetworkModel to live loopback RPCs (int8 codec) and
    return the fitted bandwidth — the yardstick the kernel bytes/s are
    judged against."""
    from repro.exchange.socket_transport import TcpTransport
    from repro.launch.embed_server import serve_in_thread

    samples = []
    rng = np.random.default_rng(0)
    for hidden in hidden_sweep:
        with serve_in_thread(3, hidden) as handle:
            tr = TcpTransport(3, hidden, [handle.address], codec="int8")
            try:
                for n in n_sweep:
                    gids = np.arange(n)
                    vals = [rng.normal(size=(n, hidden)).astype(np.float32)
                            for _ in range(2)]
                    tr.register(gids)
                    tr.write(gids, vals)
                    tr.gather(gids)
                samples += [(s.payload_bytes, 1, s.n_rows * s.layers,
                             s.measured_s)
                            for s in tr.rpc_samples
                            if s.fanout == 1 and s.op in ("write", "gather")]
            finally:
                tr.close()
    return float(fit_network_model(samples, relative=True)
                 .bandwidth_bytes_per_s)


def main() -> None:
    full = "--full" in sys.argv
    rng = np.random.default_rng(0)
    h = 128
    if full:
        cases = [
            case_gather_quantize(16384, 8192, h, rng),
            case_dequant_scatter(16384, 8192, h, rng),
            case_dequant_aggregate(8192, 4096, 5, h, rng),
        ]
        bw_fit = fitted_bandwidth((32, 64, 128), (256, 1024, 4096))
    else:
        cases = [
            case_gather_quantize(4096, 2048, h, rng),
            case_dequant_scatter(4096, 2048, h, rng),
            case_dequant_aggregate(2048, 1024, 5, h, rng),
        ]
        bw_fit = fitted_bandwidth((32, 128), (256, 1024))

    default_bw = NetworkModel().bandwidth_bytes_per_s
    for c in cases:
        c["speedup_x"] = c["unfused_s"] / c["fused_s"]
        c["wire_bytes_per_s"] = c["wire_bytes"] / c["fused_s"]
        c["x_over_fitted_bw"] = c["wire_bytes_per_s"] / bw_fit
        r = c["roofline"]
        print(f"{c['name']},{c['fused_s'] * 1e6:.0f},"
              f"unfused_us={c['unfused_s'] * 1e6:.0f} "
              f"speedup={c['speedup_x']:.2f}x "
              f"tpu_memory_us={r['memory_s'] * 1e6:.2f} "
              f"tpu_compute_us={r['compute_s'] * 1e6:.2f} "
              f"dominant={r['dominant']} "
              f"hbm_savings={r['hbm_savings_x']:.2f}x "
              f"wire_MBps={c['wire_bytes_per_s'] / 1e6:.0f} "
              f"x_fitted_bw={c['x_over_fitted_bw']:.1f}", flush=True)
    print(f"wire_fit,0,fitted_bandwidth_MBps={bw_fit / 1e6:.1f} "
          f"default_MBps={default_bw / 1e6:.1f}", flush=True)

    out = {
        "mode": "full" if full else "quick",
        "backend": jax.default_backend(),
        "fitted_bandwidth_Bps": bw_fit,
        "default_bandwidth_Bps": default_bw,
        "kernels": cases,
    }
    path = REPO_ROOT / "BENCH_kernels.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"bench_kernels,0,wrote={path}", flush=True)


if __name__ == "__main__":
    main()
