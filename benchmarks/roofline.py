"""Roofline analysis (deliverable g) from the dry-run artifacts.

Reads results/dryrun.json (produced by ``repro.launch.dryrun --all``) and
derives, per (arch × shape) on the single-pod mesh, three per-chip terms:

  compute term    = census_FLOPs / peak_FLOP/s
  memory term     = max(analytic_min_HBM_traffic, …) / HBM_bw
  collective term = census_collective_bytes / ICI link bw

Sources & caveats (measured on this container, see EXPERIMENTS.md):
  * ``compiled.cost_analysis()`` counts while-loop bodies ONCE, so for
    scanned models it under-reports by the loop trip product.  We instead
    use ``repro.launch.hlo_census`` — a loop-aware walk of the partitioned
    HLO that multiplies each computation by its execution count
    (calibrated to match cost_analysis exactly on loop-free programs).
  * The census HBM proxy (sum of top-level instruction results) counts
    VMEM-resident temporaries and is a loose upper bound; the *memory
    term* therefore uses a first-principles minimum-traffic model
    (weights re-read per microbatch, saved activations written+read once,
    KV cache streamed per decode step) — the classic napkin-roofline
    numerator — with the census bound reported alongside.

Also reported: MODEL_FLOPS = 6·N_active·tokens (train) or 2·N_active·tokens
(forward-only), the useful/compiled ratio (catches remat/dispatch waste),
the dominant term, and a one-line "what moves it".
"""

from __future__ import annotations

import json
import pathlib
import sys

from repro.configs import SHAPES, get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.launch.steps import default_microbatches

RESULTS = pathlib.Path("results/dryrun.json")
DEVICES_SINGLE = 256


def model_flops_per_chip(arch: str, shape_name: str, devices: int) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        total = 6.0 * n * shape.seq_len * shape.global_batch
    elif shape.kind == "prefill":
        total = 2.0 * n * shape.seq_len * shape.global_batch
    else:
        total = 2.0 * n * shape.global_batch
    return total / devices


def analytic_hbm_bytes(arch: str, shape_name: str, devices: int) -> float:
    """First-principles minimum HBM traffic per chip per step."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    p_dev = cfg.param_count() * 2 / devices            # bf16 weights
    d = cfg.d_model
    if shape.kind == "train":
        mb = default_microbatches(cfg, shape)
        tokens_dev = shape.seq_len * shape.global_batch / devices
        act = 2 * cfg.num_layers * tokens_dev * d * 2  # saved resid w+r
        return 2 * p_dev * mb + 6 * p_dev + act
    if shape.kind == "prefill":
        tokens_dev = shape.seq_len * shape.global_batch / devices
        return p_dev + 2 * cfg.num_layers * tokens_dev * d * 2
    # decode: read active weights once + stream the KV cache
    p_act = cfg.active_param_count() * 2 / devices
    if cfg.family == "ssm":
        d_in = cfg.ssm_expand * d
        nh = d_in // cfg.ssm_head_dim
        cache = (cfg.num_layers * shape.global_batch
                 * nh * cfg.ssm_head_dim * cfg.ssm_state * 4) / devices
    elif cfg.kv_lora_rank:
        t = min(shape.seq_len, 8192 if shape_name == "long_500k" else
                shape.seq_len)
        cache = (cfg.num_layers * shape.global_batch * t
                 * (cfg.kv_lora_rank + cfg.qk_rope_head_dim) * 2) / devices
    else:
        window = cfg.sliding_window
        t = min(shape.seq_len, window) if window else shape.seq_len
        if shape_name == "long_500k" and not window:
            t = min(shape.seq_len, 8192)
        kvh = max(cfg.num_kv_heads, 1)
        dh = cfg.resolved_head_dim
        cache = (cfg.num_layers * shape.global_batch * t
                 * 2 * kvh * dh * 2) / devices
    return p_act + cache


def analyse(records: list[dict], mesh: str = "single") -> list[dict]:
    rows = []
    for r in records:
        if r["mesh"] != mesh or not r.get("ok") or r.get("variant"):
            continue
        cen = r.get("census", {})
        flops = cen.get("flops") or r["flops"]
        coll = cen.get("collective_total",
                       r["collectives"]["total"])
        hbm_min = analytic_hbm_bytes(r["arch"], r["shape"], r["devices"])
        ct = flops / PEAK_FLOPS_BF16
        mt = hbm_min / HBM_BW
        lt = coll / ICI_BW
        terms = {"compute": ct, "memory": mt, "collective": lt}
        dom = max(terms, key=terms.get)
        mf = model_flops_per_chip(r["arch"], r["shape"], r["devices"])
        ratio = mf / flops if flops > 0 else float("nan")
        note = {
            "compute": "raise arithmetic efficiency: cheaper remat policy, "
                       "causal-skip in blocked attention, fewer dispatch "
                       "FLOPs",
            "memory": "cut HBM traffic: fewer weight re-reads "
                      "(microbatches), smaller saved activations, "
                      "quantized cache",
            "collective": "reshard to shrink per-layer all-gathers / "
                          "overlap collectives with compute (the paper's "
                          "§4.2 push-overlap, applied to ICI)",
        }[dom]
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "compute_s": ct, "memory_s": mt, "collective_s": lt,
            "dominant": dom, "model_flops": mf, "census_flops": flops,
            "useful_ratio": ratio,
            "hbm_census_gib": cen.get("hbm_bytes", 0) / 2**30,
            "mem_gib": (r["memory"].get("argument_size_in_bytes", 0)
                        + r["memory"].get("temp_size_in_bytes", 0)) / 2**30,
            "note": note,
        })
    return rows


def markdown_table(rows: list[dict]) -> str:
    out = ["| arch | shape | compute s | memory s | collective s | "
           "dominant | useful/HLO | args+temp GiB | what moves it |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['mem_gib']:.1f} | {r['note']} |")
    return "\n".join(out)


def main():
    if not RESULTS.exists():
        print("roofline,0,missing-results-run-dryrun-first")
        return
    records = json.loads(RESULTS.read_text())
    rows = analyse(records)
    for r in rows:
        dom_s = {"compute": r["compute_s"], "memory": r["memory_s"],
                 "collective": r["collective_s"]}[r["dominant"]]
        print(f"roofline/{r['arch']}/{r['shape']},{dom_s * 1e6:.0f},"
              f"dominant={r['dominant']};useful_ratio={r['useful_ratio']:.2f};"
              f"mem_gib={r['mem_gib']:.1f}", flush=True)
    if "--markdown" in sys.argv:
        print()
        print(markdown_table(rows))


if __name__ == "__main__":
    main()
