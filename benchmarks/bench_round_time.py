"""Fig. 7: median round time and its pull/train/dynamic-pull/push
components per strategy per graph."""

from __future__ import annotations

from repro.core import default_strategies

from .common import FULL, QUICK, emit, graph_for, quick_mode, run_strategy, \
    summarize


def main():
    mode = QUICK if quick_mode() else FULL
    for gname in mode["graphs"]:
        g, bs = graph_for(gname)
        for sname, strat in default_strategies().items():
            _, stats = run_strategy(g, bs, strat, rounds=mode["rounds"])
            s = summarize(stats)
            emit(f"round_time/{gname}/{sname}", s,
                 f"pull={s['pull']:.3f};train={s['train']:.3f};"
                 f"dyn={s['dyn_pull']:.3f};push={s['push']:.3f};"
                 f"stored={s['stored']}")


if __name__ == "__main__":
    main()
