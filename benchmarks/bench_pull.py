"""Fig. 12: pull-phase prefetch analysis on Products — nodes per
on-demand RPC, RPC service time, and total pull time vs batch size for
OPP_T0 / OPP_T25 / OPP_R25."""

from __future__ import annotations

import numpy as np

from repro.core import Strategy

from .common import QUICK, FULL, emit, graph_for, quick_mode, run_strategy, \
    summarize


def variants():
    base = dict(overlap_push=True, retention_limit=4)
    return {
        "T0": Strategy("OPP_T0", prefetch_frac=0.0, **base),
        "T25": Strategy("OPP_T25", prefetch_frac=0.25, **base),
        "R25": Strategy("OPP_R25", prefetch_frac=0.25, random_subset=True,
                        **base),
    }


def main():
    mode = QUICK if quick_mode() else FULL
    gname = "products" if not quick_mode() else "reddit"
    g, bs = graph_for(gname)
    for name, strat in variants().items():
        _, stats = run_strategy(g, bs, strat, rounds=mode["rounds"])
        s = summarize(stats)
        sizes = np.concatenate([np.asarray(st.pull_rpc_sizes, np.int64)
                                for st in stats]) \
            if any(st.pull_rpc_sizes for st in stats) else np.zeros(1)
        emit(f"pull/{gname}/{name}", s,
             f"rpc_med_nodes={np.median(sizes):.0f};"
             f"rpc_p90_nodes={np.percentile(sizes, 90):.0f};"
             f"dyn_s={s['dyn_pull']:.3f};pull_s={s['pull']:.3f}")

    # Fig. 12d: total pull time vs batch size (T25)
    for bs2 in (64, 128, 256, 512):
        _, stats = run_strategy(g, bs2, variants()["T25"],
                                rounds=max(3, mode["rounds"] // 2))
        s = summarize(stats)
        emit(f"pull_batch/{gname}/bs{bs2}", s,
             f"pull_total_s={s['pull'] + s['dyn_pull']:.3f}")


if __name__ == "__main__":
    main()
