"""Beyond-paper table: OptimES ideas on federated LLM training.

Two silos × 4 local steps on a reduced smollm; compares dense FedAvg
(EmbC analogue: ship everything), top-k delta pruning (§4.1 analogue)
and pruning + one-round-stale aggregation (§4.2 overlap analogue).
Reports final loss and modelled bytes shipped per round."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.core.fedopt import FedOptConfig, FederatedLMTrainer
from repro.data import synthetic_batches
from repro.optim import adamw

from .common import quick_mode


def _batches(cfg, fed, seed=0):
    gens = [synthetic_batches(cfg, batch=2, seq=32, seed=seed + 31 * s)
            for s in range(fed.num_silos)]
    while True:
        per = []
        for g in gens:
            steps = [next(g) for _ in range(fed.local_steps)]
            per.append(jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *steps))
        yield jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per)


def main():
    cfg = get_reduced("smollm-360m")
    rounds = 4 if quick_mode() else 10
    variants = {
        "dense": FedOptConfig(num_silos=2, local_steps=4),
        "top10": FedOptConfig(num_silos=2, local_steps=4,
                              delta_topk_frac=0.10),
        "top10_stale": FedOptConfig(num_silos=2, local_steps=4,
                                    delta_topk_frac=0.10,
                                    stale_aggregation=True),
    }
    for name, fed in variants.items():
        tr = FederatedLMTrainer(cfg, adamw(2e-3), fed)
        gen = _batches(cfg, fed)
        loss = float("nan")
        for _ in range(rounds):
            loss = tr.round(next(gen))["loss"]
        mb = tr.comm_bytes_per_round() / 2**20
        print(f"fedopt/smollm/{name},0,"
              f"final_loss={loss:.3f};ship_mib_per_round={mb:.2f}",
              flush=True)


if __name__ == "__main__":
    main()
