"""Dynamic-graph bench: edge-cut trajectory and accuracy under growth.

One seeded :class:`~repro.dyngraph.GrowthSchedule` is replayed through
the real overlay (``GraphOverlay.apply`` per event) while three
placement policies track the grown graph:

* ``admit``    — single-pass streaming admission (``passes=0``), the
  incremental baseline every reduction is measured against;
* ``restream`` — admission plus ``PASSES`` warm restreaming passes
  after each event (the product path, ``Strategy.restream_passes``);
* ``rebuild``  — periodic full LDG re-partition from scratch at each
  event.  A cold single-pass stream forgets everything the warm
  partition knew, so restreaming beats it on *both* cost and cut —
  the measured case for incremental maintenance over periodic
  rebuilds.

Also runs the in-process trainer over a growth schedule for the
accuracy trajectory, and times overlay compaction against a
from-scratch rebuild of the final store.  Everything lands in
``BENCH_dyngraph.json``; CSV rows go to stdout for the CI log.
"""

from __future__ import annotations

import json
import pathlib
import tempfile
import time

import numpy as np

from repro.dyngraph import (GraphOverlay, GrowthSchedule, RestreamConfig,
                            compact, edge_cut_stream, repartition)
from repro.fedsvc.runtime import RunConfig
from repro.graphstore import ldg_partition, open_store

from .common import quick_mode

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
CLIENTS = 4
PASSES = 5


def cut_study(sched: GrowthSchedule, k: int) -> dict:
    """Edge-cut trajectory of the three policies over the schedule."""
    with tempfile.TemporaryDirectory(prefix="bench_dyn_") as root:
        base = sched.build_base(str(root) + "/base")
        ov = GraphOverlay(base)
        seed_part = ldg_partition(base, k, seed=0)
        admit_cfg = RestreamConfig(passes=0)
        restream_cfg = RestreamConfig(passes=PASSES)
        p_admit = np.asarray(seed_part, np.int32).copy()
        p_restream = p_admit.copy()
        traj = []
        restream_s = rebuild_s = 0.0
        for e in range(1, sched.num_events + 1):
            ov.apply(*sched.event_batch(e))
            p_admit = repartition(ov, p_admit, k, admit_cfg)
            t0 = time.perf_counter()
            p_restream = repartition(ov, p_restream, k, restream_cfg)
            restream_s += time.perf_counter() - t0
            t0 = time.perf_counter()
            p_rebuild = ldg_partition(ov, k, seed=0)
            rebuild_s += time.perf_counter() - t0
            traj.append({
                "event": e,
                "vertices": int(ov.num_vertices),
                "edges": int(ov.num_edges),
                "cut_admit": edge_cut_stream(ov, p_admit),
                "cut_restream": edge_cut_stream(ov, p_restream),
                "cut_rebuild": edge_cut_stream(ov, p_rebuild),
            })
        final = traj[-1]
        reduction = 100.0 * (final["cut_admit"] - final["cut_restream"]) \
            / max(1, final["cut_admit"])
        # compaction vs from-scratch build of the same final graph
        t0 = time.perf_counter()
        compact(ov, str(root) + "/compacted", name="dyn_full")
        compact_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        full = sched.build_full(str(root) + "/full")
        rebuild_store_s = time.perf_counter() - t0
        compacted = open_store(str(root) + "/compacted")
        matches = bool(
            np.array_equal(np.asarray(compacted.indices),
                           np.asarray(full.indices))
            and np.array_equal(np.asarray(compacted.features),
                               np.asarray(full.features)))
        return {
            "schedule": sched.to_dict(),
            "clients": k,
            "restream_passes": PASSES,
            "trajectory": traj,
            "restream_cut_reduction_pct": reduction,
            "rebuild_cut_reduction_pct": 100.0
            * (final["cut_admit"] - final["cut_rebuild"])
            / max(1, final["cut_admit"]),
            "restream_total_s": restream_s,
            "rebuild_total_s": rebuild_s,
            "compact_s": compact_s,
            "full_build_s": rebuild_store_s,
            "compaction_matches_rebuild": matches,
        }


def accuracy_study(rounds: int) -> dict:
    """In-process trainer over a growth schedule: accuracy + graph-size
    trajectory (strategy D — the growth plane itself, no exchange)."""
    sched = GrowthSchedule(scale=10, seed=7, base_frac=0.5, num_events=2,
                           start_round=1, num_classes=8, feat_dim=16)
    with tempfile.TemporaryDirectory(prefix="bench_dyn_tr_") as root:
        sched.build_base(str(root) + "/base")
        cfg = RunConfig(graph="store:" + str(root) + "/base",
                        growth=sched.to_dict(), strategy="D",
                        num_clients=2, batch_size=64, epochs_per_round=2,
                        seed=0, rounds=rounds)
        tr = cfg.build_trainer()
        hist = tr.train(rounds)
        return {
            "schedule": sched.to_dict(),
            "rounds": rounds,
            "accuracy": [float(r.accuracy) for r in hist],
            "vertices_per_round": [
                sched.frontier(sched.epoch_for_round(r))
                for r in range(rounds)],
            "final_vertices": int(tr.g.num_vertices),
        }


def main() -> None:
    quick = quick_mode()
    sched = GrowthSchedule(scale=11 if quick else 12, seed=1 if quick
                           else 0, base_frac=0.5, num_events=8,
                           num_classes=8, feat_dim=16)
    cuts = cut_study(sched, CLIENTS)
    accs = accuracy_study(4 if quick else 8)
    record = {"mode": "quick" if quick else "full",
              "cut_study": cuts, "accuracy_study": accs}
    for row in cuts["trajectory"]:
        print(f"dyn_cut_event{row['event']},{row['cut_admit']},"
              f"restream={row['cut_restream']} "
              f"rebuild={row['cut_rebuild']}", flush=True)
    print(f"dyn_cut_reduction,"
          f"{cuts['restream_cut_reduction_pct']:.1f},"
          f"rebuild={cuts['rebuild_cut_reduction_pct']:.1f} "
          f"compact_ok={cuts['compaction_matches_rebuild']}", flush=True)
    print(f"dyn_accuracy,{accs['accuracy'][-1]:.4f},"
          f"V={accs['final_vertices']}", flush=True)
    if not quick:
        out = REPO_ROOT / "BENCH_dyngraph.json"
        out.write_text(json.dumps(record, indent=2) + "\n")
        print(f"wrote {out}", flush=True)


if __name__ == "__main__":
    main()
