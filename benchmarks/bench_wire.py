"""Live-wire calibration: modelled vs measured embedding-RPC time.

The paper's §5.4 cost analysis — and every modelled number this repo
reports — rests on the analytic ``NetworkModel``.  This bench closes
the loop: it launches real ``repro.launch.embed_server`` listeners on
loopback, drives batched write/gather RPCs through ``TcpTransport``
across RPC sizes and two hidden widths, then

  1. verifies the measured on-wire payload bytes match
     ``NetworkModel.embedding_bytes`` *exactly* for fp32 and int8,
  2. fits (bandwidth_bytes_per_s, rpc_overhead_s,
     per_embedding_overhead_s) per codec from the measured samples
     (``repro.core.cost_model.fit_network_model``), and
  3. reports the fitted-model vs measured residual per RPC size.

Design notes, learned the honest way: codec encode/decode is real
per-embedding serialisation work (the §5.4 calibration already folds
serialisation into ``per_embedding_overhead``) and differs per codec —
on loopback int8's quantisation compute outweighs its byte savings, so
a single fit across codecs is mis-specified (it drives the bandwidth
term negative).  One model per codec, with two hidden widths in the
sweep so payload bytes and embedding count decouple, is identifiable.

Acceptance (loopback): residual < 50% for batched RPCs of >= 1k rows,
and zero payload-byte mismatches.

Output CSV rows: ``name,us_per_call,derived`` like every other bench.
"""

from __future__ import annotations

import numpy as np

from repro.core.cost_model import NetworkModel, fit_network_model
from repro.exchange import TcpTransport, get_codec
from repro.launch.embed_server import serve_in_thread

from .common import emit

LAYERS = 3                      # L; the server stores L-1 tables
HIDDENS = (32, 128)
SIZES = (64, 256, 1024, 4096)
REPS = 10                       # per (codec, hidden, size), after warmup
CODECS = ("fp32", "int8")


def _drive(transport: TcpTransport, gids: np.ndarray, hidden: int,
           reps: int, rng: np.random.Generator) -> None:
    """reps × (write + gather) batched RPCs over the full id set."""
    for _ in range(reps):
        vals = [rng.standard_normal((len(gids), hidden)).astype(np.float32)
                for _ in range(LAYERS - 1)]
        transport.write(gids, vals)
        transport.gather(gids)


def collect_samples():
    """→ (mins, byte_mismatches).

    ``mins[(codec, hidden, n, op)] = (payload_bytes, embeddings,
    min measured s)`` — min over reps is the noise-floor estimate of
    the deterministic RPC cost (this container shares cores; medians
    carry multi-ms scheduler stragglers that swamp sub-ms RPCs)."""
    net0 = NetworkModel()
    mins: dict = {}
    mismatches = 0
    for hidden in HIDDENS:
        handle = serve_in_thread(LAYERS, hidden)
        try:
            for codec_name in CODECS:
                bps = get_codec(codec_name).bytes_per_scalar(hidden)
                tr = TcpTransport(LAYERS, hidden, [handle.address],
                                  codec=codec_name)
                tr.register(np.arange(max(SIZES)))
                for n in SIZES:
                    gids = np.arange(n)
                    expect = net0.embedding_bytes(
                        n, hidden, LAYERS - 1, bytes_per_scalar=bps)
                    _drive(tr, gids, hidden, 2,
                           np.random.default_rng(0))        # warmup
                    tr.rpc_samples.clear()
                    _drive(tr, gids, hidden, REPS,
                           np.random.default_rng(n))
                    for s in tr.rpc_samples:
                        if s.payload_bytes != expect:
                            mismatches += 1
                        if s.fanout != 1:   # only clean per-RPC clocks
                            continue
                        key = (codec_name, hidden, n, s.op)
                        prev = mins.get(key)
                        if prev is None or s.measured_s < prev[2]:
                            mins[key] = (s.payload_bytes,
                                         s.n_rows * s.layers, s.measured_s)
                tr.close()
        finally:
            handle.stop()
    return mins, mismatches


def main() -> None:
    mins, byte_mismatches = collect_samples()
    emit("wire-bytes-exact", {"median_round_s": 0.0},
         f"mismatches={byte_mismatches} (payload vs embedding_bytes, "
         f"codecs={'+'.join(CODECS)})")

    worst_1k = 0.0
    for codec_name in CODECS:
        # fit the batched regime (n >= 256): the trainer's upfront pulls
        # and pushes are thousands of rows per RPC; tiny RPCs are
        # dispatch-overhead-dominated and reported below but not fitted.
        rows = [(b, 1, e, t) for (c, _, n, _), (b, e, t) in mins.items()
                if c == codec_name and n >= 256]
        fitted = fit_network_model(rows, relative=True)
        emit(f"fitted-{codec_name}", {"median_round_s": 0.0},
             f"bandwidth_B_per_s={fitted.bandwidth_bytes_per_s:.3e} "
             f"rpc_overhead_s={fitted.rpc_overhead_s:.3e} "
             f"per_embedding_overhead_s="
             f"{fitted.per_embedding_overhead_s:.3e}")
        for hidden in HIDDENS:
            bps = get_codec(codec_name).bytes_per_scalar(hidden)
            for n in SIZES:
                ts = [t for (c, h, m, _), (_, _, t) in mins.items()
                      if c == codec_name and h == hidden and m == n]
                measured = float(np.mean(ts))   # write-min + gather-min
                modelled = fitted.transfer_time(n, hidden, LAYERS - 1,
                                                bytes_per_scalar=bps)
                resid = abs(modelled - measured) / measured
                if n >= 1024:
                    worst_1k = max(worst_1k, resid)
                emit(f"rpc-{codec_name}-h{hidden}-n{n}",
                     {"median_round_s": measured},
                     f"measured_ms={measured * 1e3:.3f} "
                     f"modelled_ms={modelled * 1e3:.3f} resid={resid:.1%}")

    status = "PASS" if worst_1k < 0.5 and byte_mismatches == 0 else "FAIL"
    emit("calibration", {"median_round_s": 0.0},
         f"{status} worst_resid_ge_1k={worst_1k:.1%} (target < 50%)")
    if status == "FAIL":
        raise SystemExit(1)          # make the CI gate actually gate


if __name__ == "__main__":
    main()
