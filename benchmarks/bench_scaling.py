"""Fig. 13 client scaling + graph-plane vertex-count scaling.

Two sweeps:

* **clients** — 4 → 6 → 8 clients for the main strategies (the paper's
  Fig. 13), unchanged from the seed.
* **graphplane** — R-MAT vertex counts 16k → 1M through the out-of-core
  plane: each size builds an mmap store + LDG partition + 8 client
  shards in a *subprocess* (``repro.launch.build_store`` self-reports
  its peak RSS, so the builder's bounded-memory claim is measured, not
  asserted), then runs one federated round in-process off the store.
  Quick mode stops at 2^16 vertices; ``--full`` adds 2^17 and the
  1M-vertex 2^20 point (the ISSUE-5 acceptance row: build + partition
  + one round with builder RSS well under the materialized edge list).
"""

from __future__ import annotations

import json
import os
import shutil
import subprocess
import sys
import tempfile
import time

from repro.core import default_strategies, peak_accuracy

from .common import QUICK, FULL, emit, graph_for, quick_mode, \
    run_strategy, target_margin, \
    summarize, tta

CLIENTS = (4, 6, 8)
STRATS = ("E", "O", "OPP", "OPG")

RMAT_SCALES_QUICK = (14, 16)          # 16k / 65k vertices
RMAT_SCALES_FULL = (14, 16, 17, 20)   # ... 131k / 1M vertices
EDGE_FACTOR = 8
GP_CLIENTS = 8


def client_sweep() -> None:
    mode = QUICK if quick_mode() else FULL
    graphs = ("reddit",) if quick_mode() else ("reddit", "products")
    for gname in graphs:
        g, bs = graph_for(gname)
        for k in CLIENTS:
            results = {}
            for sname in STRATS:
                strat = default_strategies()[sname]
                _, stats = run_strategy(g, bs, strat, clients=k,
                                        rounds=mode["rounds"])
                results[sname] = stats
            target = min(peak_accuracy(s)
                         for s in results.values()) - target_margin()
            for sname, stats in results.items():
                s = summarize(stats)
                emit(f"scaling/{gname}/k{k}/{sname}", s,
                     f"peak={s['peak_acc']:.4f};"
                     f"tta_s={tta(stats, target):.2f}")


def graphplane_sweep() -> None:
    scales = RMAT_SCALES_QUICK if quick_mode() else RMAT_SCALES_FULL
    for scale in scales:
        out = tempfile.mkdtemp(prefix=f"bench_rmat{scale}_")
        try:
            proc = subprocess.run(
                [sys.executable, "-m", "repro.launch.build_store",
                 "--out", out, "--rmat-scale", str(scale),
                 "--edge-factor", str(EDGE_FACTOR),
                 "--graph-seed", "1", "--seed", "0",
                 "--clients", str(GP_CLIENTS)],
                capture_output=True, text=True,
                env={**os.environ,
                     "PYTHONPATH": "src" + os.pathsep
                     + os.environ.get("PYTHONPATH", "")})
            if proc.returncode != 0:
                print(f"graphplane/rmat{scale}: build_store failed "
                      f"(rc={proc.returncode})\n{proc.stderr}",
                      flush=True)
                continue
            st = json.loads(proc.stdout.strip().splitlines()[-1])
            # RSS headroom vs the edge list the builder never held:
            # symmetrized int64 (src, dst) pairs
            edgelist_mb = st["num_edges"] * 16 / 1e6
            emit(f"graphplane/rmat{scale}/build",
                 {"median_round_s": st["build_s"]},
                 f"edges={st['num_edges']};"
                 f"edges_per_s={st['build_edges_per_s']};"
                 f"build_rss_mb={st['build_peak_rss_mb']:.0f};"
                 f"rss_mb={st['peak_rss_mb']:.0f};"
                 f"edgelist_mb={edgelist_mb:.0f}")
            emit(f"graphplane/rmat{scale}/partition",
                 {"median_round_s": st["partition_s"]},
                 f"vertices_per_s={st['partition_vertices_per_s']};"
                 f"boundary={st['boundary_pull_nodes']};"
                 f"shard_s={st['shard_s']}")

            from repro.fedsvc.runtime import RunConfig
            cfg = RunConfig(graph=f"store:{out}", num_clients=GP_CLIENTS,
                            strategy="E", hidden=16, fanout=3,
                            batch_size=32, epochs_per_round=1,
                            rounds=1, seed=0)
            tr = cfg.build_trainer()
            t0 = time.perf_counter()
            stats = tr.train(1)
            t_round = time.perf_counter() - t0
            emit(f"graphplane/rmat{scale}/round",
                 {"median_round_s": t_round},
                 f"modelled_s={stats[0].round_time:.3f};"
                 f"acc={stats[0].accuracy:.4f};"
                 f"stored={stats[0].embeddings_stored}")
        finally:
            shutil.rmtree(out, ignore_errors=True)


def main():
    client_sweep()
    graphplane_sweep()


if __name__ == "__main__":
    main()
