"""Fig. 13: client scaling (4 → 6 → 8 clients) for the main strategies."""

from __future__ import annotations

from repro.core import default_strategies, peak_accuracy

from .common import QUICK, FULL, emit, graph_for, quick_mode, \
    run_strategy, target_margin, \
    summarize, tta

CLIENTS = (4, 6, 8)
STRATS = ("E", "O", "OPP", "OPG")


def main():
    mode = QUICK if quick_mode() else FULL
    graphs = ("reddit",) if quick_mode() else ("reddit", "products")
    for gname in graphs:
        g, bs = graph_for(gname)
        for k in CLIENTS:
            results = {}
            for sname in STRATS:
                strat = default_strategies()[sname]
                _, stats = run_strategy(g, bs, strat, clients=k,
                                        rounds=mode["rounds"])
                results[sname] = stats
            target = min(peak_accuracy(s) for s in results.values()) - target_margin()
            for sname, stats in results.items():
                s = summarize(stats)
                emit(f"scaling/{gname}/k{k}/{sname}", s,
                     f"peak={s['peak_acc']:.4f};"
                     f"tta_s={tta(stats, target):.2f}")


if __name__ == "__main__":
    main()
