"""Serving-plane bench: open-loop query stream against a GraphStore.

Builds an R-MAT mmap store in a subprocess (2^14 vertices quick,
the 1M-vertex 2^20 point with ``--full``), trains one federated round
off it, exports the model + boundary embeddings into the gnnserve
plane, then drives an **open-loop** Zipf-skewed vertex-query stream
through the continuous batcher: a producer thread submits at a fixed
offered rate (calibrated to ~60% of measured closed-loop capacity, so
queueing is real but bounded) while the frontend driver steps the
batchers; latency is measured per request from enqueue to retire.

Two sweeps, both emitted as CSV rows *and* collected into the
machine-readable perf-trajectory file ``BENCH_gnnserve.json``:

* **cache** — hot-embedding cache capacity at 1% / 10% / 100% of the
  deployment's boundary rows: hit rate vs p50/p99 latency/throughput.
* **early-exit** — confidence thresholds 1.0 / 0.9 / 0.6 / 0.3 at full
  cache: latency reduction vs argmax agreement with the threshold-1.0
  reference on the identical query sequence.
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import subprocess
import sys
import tempfile
import threading
import time

import numpy as np

from repro.fedsvc.runtime import RunConfig
from repro.gnnserve import build_serving
from repro.gnnserve.frontend import _FrontState

from .common import emit, quick_mode

EDGE_FACTOR = 8
CLIENTS = 4
ZIPF_A = 1.1
REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]


def build_store(scale: int) -> str:
    out = tempfile.mkdtemp(prefix=f"bench_serve_rmat{scale}_")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.build_store",
         "--out", out, "--rmat-scale", str(scale),
         "--edge-factor", str(EDGE_FACTOR),
         "--graph-seed", "1", "--seed", "0", "--clients", str(CLIENTS)],
        capture_output=True, text=True,
        env={**os.environ,
             "PYTHONPATH": "src" + os.pathsep
             + os.environ.get("PYTHONPATH", "")})
    if proc.returncode != 0:
        shutil.rmtree(out, ignore_errors=True)
        raise RuntimeError(f"build_store failed rc={proc.returncode}\n"
                           f"{proc.stderr}")
    return out


def zipf_vids(n: int, num_vertices: int, seed: int) -> np.ndarray:
    """Zipf-skewed query stream: popularity rank is a seeded permutation
    of the vertex ids, so hot vertices are spread across shards."""
    rng = np.random.default_rng((seed, 7919))
    ranks = (rng.zipf(ZIPF_A, size=n) - 1) % num_vertices
    perm = rng.permutation(num_vertices)
    return perm[ranks].astype(np.int64)


def warmup(plane, vids: np.ndarray) -> None:
    """Trigger every (shard, depth) jit compile before timing."""
    for ci, eng in plane.engines.items():
        mine = vids[np.array([plane.part[v] for v in vids]) == ci][:4]
        if len(mine) == 0:
            continue
        seeds = [eng.local_id(int(v)) for v in mine]
        for d in eng.depth_schedule:
            eng.predict_at_depth(seeds, [1.0] * len(seeds), d)


def closed_loop_capacity(plane, vids: np.ndarray,
                         thresholds: np.ndarray) -> float:
    """Requests/s with the batchers saturated (everything pre-queued)."""
    for v, t in zip(vids, thresholds):
        plane.submit(int(v), float(t))
    t0 = time.perf_counter()
    plane.drain()
    dt = time.perf_counter() - t0
    for b in plane.batchers.values():
        b.pop_completed()
    return len(vids) / dt


def open_loop(plane, vids: np.ndarray, thresholds: np.ndarray,
              rate: float) -> dict:
    """Offered-rate stream through the frontend driver; returns latency
    percentiles, throughput, and the request→prediction map."""
    state = _FrontState(plane)
    driver = threading.Thread(target=state.drive, daemon=True)
    driver.start()
    n = len(vids)
    t_start = time.perf_counter()

    def produce():
        for i, (v, t) in enumerate(zip(vids, thresholds)):
            lag = t_start + i / rate - time.perf_counter()
            if lag > 0:
                time.sleep(lag)
            with state.cond:
                plane.submit(int(v), float(t))
                state.cond.notify_all()

    prod = threading.Thread(target=produce, daemon=True)
    prod.start()
    deadline = time.perf_counter() + n / rate + 120.0
    with state.cond:
        while len(state.results) < n:
            if time.perf_counter() > deadline:
                raise RuntimeError(
                    f"open-loop stalled: {len(state.results)}/{n}")
            state.cond.wait(0.05)
    wall = time.perf_counter() - t_start
    prod.join()
    state.stop.set()
    driver.join(5.0)
    res = sorted(state.results.values(), key=lambda r: r.rid)
    lat = np.array([r.latency for r in res])
    return {
        "offered_rps": rate,
        "throughput_rps": n / wall,
        "p50_ms": float(np.percentile(lat, 50) * 1e3),
        "p99_ms": float(np.percentile(lat, 99) * 1e3),
        "mean_ms": float(lat.mean() * 1e3),
        "preds": np.array([r.pred for r in res], np.int32),
        "exits": {str(k): v for r in [plane.stats()]
                  for k, v in r["exits_by_depth"].items()},
    }


def fresh_plane(bundle, cache_rows: int):
    return build_serving(bundle, cache_rows=cache_rows, serve_fanout=4,
                         batch_size=64)


def measure_point(plane, vids, thrs, rate):
    """One sweep point: jit warmup, closed-loop warm-fill over the whole
    stream (brings the cache to steady state *at this capacity*), a warm
    closed-loop capacity probe, stats reset, then the timed open-loop
    pass — so reported hit rate and latency are steady state, not the
    cold-fill transient."""
    warmup(plane, vids)
    fill_rps = closed_loop_capacity(plane, vids, thrs)
    cap_rps = closed_loop_capacity(plane, vids[:600], thrs[:600])
    plane.cache.reset_stats()
    r = open_loop(plane, vids, thrs, rate)
    r["fill_rps"] = fill_rps
    r["capacity_rps"] = cap_rps
    return r


def main():
    scale = 14 if quick_mode() else 20
    n_requests = 1500 if quick_mode() else 4000
    store_dir = build_store(scale)
    record = {"mode": "quick" if quick_mode() else "full",
              "rmat_scale": scale, "edge_factor": EDGE_FACTOR,
              "clients": CLIENTS, "zipf_a": ZIPF_A,
              "n_requests": n_requests}
    try:
        cfg = RunConfig(graph=f"store:{store_dir}", num_clients=CLIENTS,
                        strategy="E", hidden=16, fanout=3, batch_size=32,
                        epochs_per_round=1, rounds=1, seed=0)
        tr = cfg.build_trainer()
        tr.pretrain_round()
        tr.run_round(0, 0.0)
        bundle = tr.export_for_serving()
        num_vertices = tr.g.num_vertices
        boundary_rows = sum(len(sh.pull_nodes) for sh in
                            bundle["shards"].values()) * (cfg.num_layers - 1)
        record["vertices"] = int(num_vertices)
        record["boundary_rows"] = int(boundary_rows)

        vids = zipf_vids(n_requests, num_vertices, seed=0)
        ones = np.ones(n_requests, np.float32)

        # calibrate the offered rate once at full cache / threshold 1.0:
        # cold pass fills the cache, warm pass is the service rate; the
        # fixed open-loop rate (0.6× warm, capped so the Python producer
        # keeps up) then deliberately saturates the weak sweep points
        cal = fresh_plane(bundle, max(1, boundary_rows))
        warmup(cal, vids)
        cold_cap = closed_loop_capacity(cal, vids[:600], ones[:600])
        warm_cap = closed_loop_capacity(cal, vids[:600], ones[:600])
        rate = min(1000.0, max(20.0, 0.6 * warm_cap))
        record["capacity_cold_rps"] = cold_cap
        record["capacity_warm_rps"] = warm_cap
        record["offered_rps"] = rate
        emit("gnnserve/capacity", {"median_round_s": 1.0 / warm_cap},
             f"cold_rps={cold_cap:.0f};warm_rps={warm_cap:.0f};"
             f"offered_rps={rate:.0f};vertices={num_vertices}")

        record["cache_sweep"] = []
        for frac in (0.01, 0.1, 1.0):
            rows = max(1, int(boundary_rows * frac))
            plane = fresh_plane(bundle, rows)
            r = measure_point(plane, vids, ones, rate)
            cs = plane.cache.stats()
            point = {"cache_frac": frac, "cache_rows": rows,
                     "hit_rate": cs["hit_rate"],
                     "evictions": cs["evictions"],
                     **{k: v for k, v in r.items() if k != "preds"}}
            record["cache_sweep"].append(point)
            emit(f"gnnserve/cache{int(frac * 100)}",
                 {"median_round_s": r["p50_ms"] / 1e3},
                 f"hit={cs['hit_rate']:.3f};p50_ms={r['p50_ms']:.2f};"
                 f"p99_ms={r['p99_ms']:.2f};"
                 f"rps={r['throughput_rps']:.0f};cap_rps={r['capacity_rps']:.0f}")

        # thresholds straddle the max-softmax distribution of a briefly
        # trained model; 1.0 (never exit early) is the reference
        record["threshold_sweep"] = []
        ref_preds = None
        for thr in (1.0, 0.5, 0.25, 0.1):
            plane = fresh_plane(bundle, max(1, boundary_rows))
            thrs = np.full(n_requests, thr, np.float32)
            r = measure_point(plane, vids, thrs, rate)
            if ref_preds is None:
                ref_preds = r["preds"]
            agree = float((r["preds"] == ref_preds).mean())
            point = {"threshold": thr, "agreement_vs_full": agree,
                     "exits_by_depth": r["exits"],
                     **{k: v for k, v in r.items()
                        if k not in ("preds", "exits")}}
            record["threshold_sweep"].append(point)
            emit(f"gnnserve/thr{int(thr * 100)}",
                 {"median_round_s": r["p50_ms"] / 1e3},
                 f"agree={agree:.4f};p50_ms={r['p50_ms']:.2f};"
                 f"p99_ms={r['p99_ms']:.2f};"
                 f"rps={r['throughput_rps']:.0f};"
                 f"cap_rps={r['capacity_rps']:.0f}")

        out_path = REPO_ROOT / "BENCH_gnnserve.json"
        out_path.write_text(json.dumps(record, indent=2) + "\n")
        print(f"# wrote {out_path}", flush=True)
    finally:
        shutil.rmtree(store_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
