"""Control-plane benchmark: aggregation policy and weight-wire codec.

Two experiments the sequential simulator cannot express, both run as a
real coordinator + worker deployment over loopback TCP (live embed
shards, live weight exchange):

1. **sync vs async under a straggler** (OptimES §4.2 models overlap
   *within* a client; this measures overlap *across* clients): one
   worker paced as a ``STRAGGLE``× straggler.  Synchronous FedAvg pays
   the straggler every round; FedBuff-style async aggregation
   (Strategy.buffer_size / staleness_decay) lets the fast worker keep
   contributing, so wall-clock time-to-accuracy drops.

2. **raw vs compressed weight wire**: the same sync deployment with
   ``Strategy.weight_codec="int8"`` (codec-encoded model deltas with
   error feedback, version-diff downloads) against the raw fp32
   baseline.  Reported per run: actual weight-plane payload bytes per
   round (both directions, from the coordinator's wire ledger) and the
   codec-aware modelled exchange time, next to peak accuracy — the
   acceptance target is fp32-peak accuracy within 0.5 pp at ≥3× fewer
   weight bytes per round.

Both ledgers are reported per run, same discipline as TcpTransport:
``measured`` is real wall clock from first registration (includes the
injected sleeps), ``modelled`` is the NetworkModel-based round time the
workers report (pacing-scaled ``client_total`` + modelled model
exchange priced at the bytes actually framed).

CSV rows: ``name,us_per_call,derived`` where us_per_call is the median
measured aggregation-to-aggregation time and ``derived`` carries
time-to-accuracy at the shared target plus final/peak accuracy and, for
the weight-wire sweep, bytes-per-round on the weight plane.
"""

from __future__ import annotations

import json
import pathlib

import numpy as np

from repro.fedsvc.coordinator import serve_in_thread
from repro.fedsvc.runtime import RunConfig, make_coordinator_state
from repro.fedsvc.worker import FedWorker, WorkerScenario, run_in_thread
from repro.launch.embed_server import serve_in_thread as embed_serve
from repro.obsv.metrics import REGISTRY, MetricsRegistry

from .common import emit, quick_mode

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]


def _phase_breakdown(delta: dict) -> dict:
    """Registry-snapshot delta → mean seconds per phase for this run.
    The same histograms an OP_METRICS scrape reads — the observability
    registry IS the bench's bookkeeping (no parallel ledger)."""
    out = {}
    for name in ("worker.round_s", "worker.barrier_s", "coord.agg_s",
                 "exchange.latency_s.gather", "exchange.latency_s.write",
                 "exchange.latency_s.vgather"):
        h = delta.get(name)
        if isinstance(h, dict) and h.get("count"):
            out[name] = {"count": h["count"],
                         "mean_s": h["sum"] / h["count"]}
    for name, v in delta.items():
        if name in ("coord.aggregations", "coord.weight_bytes",
                    "worker.rounds", "embed.requests") \
                and isinstance(v, (int, float)):
            out[name] = v
    return out

STRAGGLE = 2.5          # the slow worker's pacing multiplier (>= 2x)


def run_deployment(*, rounds: int, cfg_kw: dict, overrides: dict,
                   scenarios: dict[int, WorkerScenario] | None = None
                   ) -> dict:
    reg_before = REGISTRY.snapshot()
    shards = [embed_serve(cfg_kw["num_layers"], cfg_kw["hidden"])
              for _ in range(2)]
    cfg = RunConfig(strategy="E", num_clients=2, rounds=rounds,
                    overrides=overrides,
                    embed_addrs=[f"{h.host}:{h.port}" for h in shards],
                    **cfg_kw)
    state = make_coordinator_state(cfg)
    coord = serve_in_thread(state)
    scenarios = scenarios or {}
    workers = [FedWorker(cfg, [i], coord.address, worker_id=f"w{i}",
                         scenario=scenarios.get(i))
               for i in range(2)]
    threads = [run_in_thread(w) for w in workers]
    finished = coord.join(timeout=1200)
    for t in threads:
        t.join(timeout=60)
    with state.cond:
        history = list(state.history)
    coord.stop()
    for h in shards:
        h.stop()
    if not finished or not history:
        raise RuntimeError(f"{overrides} run did not finish "
                           f"({len(history)} aggregations)")
    return {"history": history,
            "accs": [h["accuracy"] for h in history],
            "wall": [h["wall_s"] for h in history],
            "modelled": [h["cum_modelled_s"] for h in history],
            "weight_bytes": [h["weight_bytes"] for h in history],
            "weight_modelled": [h["weight_modelled_s"] for h in history],
            "phases": _phase_breakdown(
                MetricsRegistry.delta(REGISTRY.snapshot(), reg_before))}


def tta(res: dict, target: float, key: str) -> float:
    for acc, t in zip(res["accs"], res[key]):
        if acc >= target:
            return t
    return float("nan")


def main() -> None:
    rounds = 6 if quick_mode() else 12
    cfg_kw = dict(graph="reddit", scale=0.05, graph_seed=3,
                  num_layers=3, hidden=32, batch_size=64,
                  epochs_per_round=3, seed=0)

    # -- 1. sync vs async under a straggler -------------------------------
    # async gets the same *update budget*: `rounds` sync rounds consume
    # 2*rounds client updates = rounds buffer drains at buffer_size=2.
    straggle = {1: WorkerScenario(pacing=STRAGGLE, seed=1)}
    sync = run_deployment(rounds=rounds, cfg_kw=cfg_kw,
                          overrides={"aggregation": "sync"},
                          scenarios=straggle)
    asyn = run_deployment(rounds=rounds, cfg_kw=cfg_kw,
                          overrides={"aggregation": "async",
                                     "buffer_size": 2,
                                     "staleness_decay": 0.5},
                          scenarios=straggle)

    # shared target: reachable by both modes (async pays staleness a
    # bit of accuracy; the win it buys is wall clock)
    target = 0.9 * min(max(sync["accs"]), max(asyn["accs"]))
    for name, res in (("sync", sync), ("async", asyn)):
        gaps = np.diff([0.0] + res["wall"])
        emit(f"{name}-straggler{STRAGGLE:g}x",
             {"median_round_s": float(np.median(gaps))},
             f"tta_measured_s={tta(res, target, 'wall'):.2f} "
             f"tta_modelled_s={tta(res, target, 'modelled'):.2f} "
             f"wall_s={res['wall'][-1]:.2f} "
             f"modelled_s={res['modelled'][-1]:.2f} "
             f"peak={max(res['accs']):.4f} "
             f"final={res['accs'][-1]:.4f} target={target:.4f}")
    speedup = tta(sync, target, "wall") / tta(asyn, target, "wall")
    print(f"# async speedup at target: {speedup:.2f}x "
          f"(straggler {STRAGGLE:g}x, buffer_size=2)", flush=True)

    # -- 2. raw vs int8+EF weight wire ------------------------------------
    # `sync` above IS the raw fp32 baseline (weight_codec=None); run the
    # same deployment with the codec-compressed weight plane.  Steady
    # state (round ≥ 1: first downloads are full models by design) is
    # the fair bytes-per-round comparison.
    comp = run_deployment(rounds=rounds, cfg_kw=cfg_kw,
                          overrides={"aggregation": "sync",
                                     "weight_codec": "int8",
                                     "weight_error_feedback": True},
                          scenarios=straggle)
    for name, res in (("weight-fp32-raw", sync), ("weight-int8+ef", comp)):
        steady = res["weight_bytes"][1:] or res["weight_bytes"]
        steady_t = res["weight_modelled"][1:] or res["weight_modelled"]
        gaps = np.diff([0.0] + res["wall"])
        emit(name,
             {"median_round_s": float(np.median(gaps))},
             f"weight_kB_round={float(np.mean(steady)) / 1e3:.1f} "
             f"weight_modelled_s_round={float(np.mean(steady_t)):.5f} "
             f"wall_s={res['wall'][-1]:.2f} "
             f"modelled_s={res['modelled'][-1]:.2f} "
             f"peak={max(res['accs']):.4f} final={res['accs'][-1]:.4f}")
    raw_b = float(np.mean(sync["weight_bytes"][1:] or sync["weight_bytes"]))
    cmp_b = float(np.mean(comp["weight_bytes"][1:] or comp["weight_bytes"]))
    dpp = (max(sync["accs"]) - max(comp["accs"])) * 100
    print(f"# weight wire int8+EF: {raw_b / cmp_b:.2f}x fewer bytes/round "
          f"({raw_b / 1e3:.1f} -> {cmp_b / 1e3:.1f} kB), "
          f"peak acc delta {dpp:+.2f} pp vs fp32 raw", flush=True)

    # -- BENCH_rounds.json: durable perf trajectory (ROADMAP item) --------
    # round time, per-phase breakdown (from the metrics registry — the
    # exact histograms OP_METRICS scrapes read), and time-to-accuracy
    # per deployment flavour.
    record = {"bench": "control_plane", "rounds": rounds,
              "quick": quick_mode(), "graph": cfg_kw["graph"],
              "scale": cfg_kw["scale"], "runs": {}}
    for name, res in (("sync_straggler", sync), ("async_straggler", asyn),
                      ("sync_weight_int8", comp)):
        gaps = np.diff([0.0] + res["wall"])
        record["runs"][name] = {
            "median_round_s": float(np.median(gaps)),
            "wall_s": res["wall"][-1],
            "modelled_s": res["modelled"][-1],
            "tta_measured_s": tta(res, target, "wall"),
            "tta_modelled_s": tta(res, target, "modelled"),
            "peak_acc": float(max(res["accs"])),
            "final_acc": float(res["accs"][-1]),
            "max_barrier_s": float(max(
                (h.get("max_barrier_s", 0.0) for h in res["history"]),
                default=0.0)),
            "weight_kB_round": float(np.mean(
                res["weight_bytes"][1:] or res["weight_bytes"])) / 1e3,
            "phases": res["phases"],
        }
    out_path = REPO_ROOT / "BENCH_rounds.json"
    out_path.write_text(json.dumps(record, indent=2, default=float) + "\n")
    print(f"# wrote {out_path}", flush=True)


if __name__ == "__main__":
    main()
