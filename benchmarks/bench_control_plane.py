"""Control-plane benchmark: sync vs async aggregation under stragglers.

The experiment the sequential simulator cannot express (OptimES §4.2
models overlap *within* a client; this measures overlap *across*
clients): a real coordinator + worker deployment over loopback TCP —
live embed shards, live weight exchange — with one worker paced as a
``STRAGGLE``× straggler.  Synchronous FedAvg pays the straggler every
round (the barrier waits); FedBuff-style async aggregation
(Strategy.buffer_size / staleness_decay) lets the fast worker keep
contributing updates, so wall-clock time-to-accuracy should drop.

Both ledgers are reported per mode, same discipline as TcpTransport:
``measured`` is real wall clock from first registration (includes the
injected sleeps), ``modelled`` is the NetworkModel-based round time the
workers report (pacing-scaled ``client_total`` + modelled model
exchange).

CSV rows: ``name,us_per_call,derived`` where us_per_call is the median
measured aggregation-to-aggregation time and ``derived`` carries
time-to-accuracy at the shared target plus final/peak accuracy.
"""

from __future__ import annotations

import sys

import numpy as np

from repro.fedsvc.coordinator import CoordinatorState, serve_in_thread
from repro.fedsvc.runtime import EvalHarness, RunConfig
from repro.fedsvc.worker import FedWorker, WorkerScenario, run_in_thread
from repro.launch.embed_server import serve_in_thread as embed_serve

from .common import emit, quick_mode

STRAGGLE = 2.5          # the slow worker's pacing multiplier (>= 2x)


def run_mode(mode: str, *, rounds: int, cfg_kw: dict,
             buffer_size: int = 2, staleness_decay: float = 0.5) -> dict:
    shards = [embed_serve(cfg_kw["num_layers"], cfg_kw["hidden"])
              for _ in range(2)]
    overrides = {"aggregation": mode, "buffer_size": buffer_size,
                 "staleness_decay": staleness_decay}
    cfg = RunConfig(strategy="E", num_clients=2, rounds=rounds,
                    overrides=overrides,
                    embed_addrs=[f"{h.host}:{h.port}" for h in shards],
                    **cfg_kw)
    harness = EvalHarness(cfg)
    state = CoordinatorState(
        num_clients=2, num_rounds=rounds, mode=mode,
        buffer_size=buffer_size, staleness_decay=staleness_decay,
        init_leaves=harness.init_leaves(),
        eval_fn=harness.evaluate_leaves)
    coord = serve_in_thread(state)
    workers = [
        FedWorker(cfg, [0], coord.address, worker_id="fast"),
        FedWorker(cfg, [1], coord.address, worker_id="slow",
                  scenario=WorkerScenario(pacing=STRAGGLE, seed=1)),
    ]
    threads = [run_in_thread(w) for w in workers]
    finished = coord.join(timeout=1200)
    for t in threads:
        t.join(timeout=60)
    with state.cond:
        history = list(state.history)
    coord.stop()
    for h in shards:
        h.stop()
    if not finished or not history:
        raise RuntimeError(f"{mode} run did not finish "
                           f"({len(history)} aggregations)")
    return {"history": history,
            "accs": [h["accuracy"] for h in history],
            "wall": [h["wall_s"] for h in history],
            "modelled": [h["cum_modelled_s"] for h in history]}


def tta(res: dict, target: float, key: str) -> float:
    for acc, t in zip(res["accs"], res[key]):
        if acc >= target:
            return t
    return float("nan")


def main() -> None:
    rounds = 6 if quick_mode() else 12
    cfg_kw = dict(graph="reddit", scale=0.05, graph_seed=3,
                  num_layers=3, hidden=32, batch_size=64,
                  epochs_per_round=3, seed=0)
    # async gets the same *update budget*: `rounds` sync rounds consume
    # 2*rounds client updates = rounds buffer drains at buffer_size=2.
    sync = run_mode("sync", rounds=rounds, cfg_kw=cfg_kw)
    asyn = run_mode("async", rounds=rounds, cfg_kw=cfg_kw)

    # shared target: reachable by both modes (async pays staleness a
    # bit of accuracy; the win it buys is wall clock)
    target = 0.9 * min(max(sync["accs"]), max(asyn["accs"]))
    for name, res in (("sync", sync), ("async", asyn)):
        gaps = np.diff([0.0] + res["wall"])
        emit(f"{name}-straggler{STRAGGLE:g}x",
             {"median_round_s": float(np.median(gaps))},
             f"tta_measured_s={tta(res, target, 'wall'):.2f} "
             f"tta_modelled_s={tta(res, target, 'modelled'):.2f} "
             f"wall_s={res['wall'][-1]:.2f} "
             f"modelled_s={res['modelled'][-1]:.2f} "
             f"peak={max(res['accs']):.4f} "
             f"final={res['accs'][-1]:.4f} target={target:.4f}")
    speedup = tta(sync, target, "wall") / tta(asyn, target, "wall")
    print(f"# async speedup at target: {speedup:.2f}x "
          f"(straggler {STRAGGLE:g}x, buffer_size=2)", flush=True)


if __name__ == "__main__":
    main()
