"""Shared harness for the paper-figure benchmarks.

Every bench_*.py regenerates one figure/table of the paper at CPU scale:
graphs come from repro.graphs.synthetic (calibrated DC-SBM stand-ins for
Arxiv/Reddit/Products/Papers), compute time is measured, network time is
modelled (repro.core.cost_model).  Output: CSV rows
``name,us_per_call,derived`` where us_per_call is the median round time in
microseconds and ``derived`` carries the figure-specific metric.
"""

from __future__ import annotations

import dataclasses
import sys

import numpy as np

from repro.core import (FederatedGNNTrainer, Strategy, default_strategies,
                        peak_accuracy, time_to_accuracy)
from repro.graphs import make_graph

# CPU-scale stand-ins: (preset, scale, batch_size) per paper dataset.
GRAPHS = {
    "arxiv": ("arxiv", 0.5, 32),
    "reddit": ("reddit", 0.5, 128),
    "products": ("products", 0.4, 256),
    "papers": ("papers", 0.3, 512),
}

QUICK = {"rounds": 6, "graphs": ("reddit", "arxiv")}
FULL = {"rounds": 20, "graphs": ("reddit", "products", "arxiv", "papers")}


def graph_for(name: str, *, seed: int = 0):
    preset, scale, bs = GRAPHS[name]
    return make_graph(preset, scale=scale, seed=seed), bs


def run_strategy(graph, batch_size, strat: Strategy, *, rounds: int,
                 clients: int = 4, conv: str = "graphconv",
                 fanout: int = 5, seed: int = 0, num_layers: int = 3,
                 **trainer_kw):
    tr = FederatedGNNTrainer(
        graph, clients, strat, conv=conv, fanout=fanout,
        batch_size=batch_size, seed=seed, num_layers=num_layers,
        **trainer_kw)
    stats = tr.train(rounds)
    return tr, stats


def summarize(stats):
    rts = [s.round_time for s in stats]
    return {
        "median_round_s": float(np.median(rts)),
        "peak_acc": peak_accuracy(stats),
        "cum_time": stats[-1].cum_time,
        "pull": float(np.median([s.phases.pull for s in stats])),
        "train": float(np.median([s.phases.train for s in stats])),
        "dyn_pull": float(np.median([s.phases.dynamic_pull for s in stats])),
        "push": float(np.median([s.phases.push_compute
                                 + s.phases.push_transfer for s in stats])),
        "stored": stats[-1].embeddings_stored,
    }


def target_margin() -> float:
    """Paper: within 1%% of the minimum peak.  Quick mode (6 rounds) uses
    3%% — the smoothed average can't sit at peak-1%% in so few rounds."""
    return 0.01 if not quick_mode() else 0.03


def tta(stats, target):
    # smooth=3: the 5-round moving average of the paper needs >=15 rounds
    # to be meaningful; quick mode runs 6.
    smooth = 5 if len(stats) >= 15 else 3
    t = time_to_accuracy(stats, target, smooth=smooth)
    return t if t is not None else float("nan")


def emit(name: str, summary: dict, derived: str):
    print(f"{name},{summary['median_round_s'] * 1e6:.0f},{derived}",
          flush=True)


def quick_mode() -> bool:
    return "--full" not in sys.argv
