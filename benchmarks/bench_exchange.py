"""Exchange-subsystem sweep: wire codec × delta pushes × server shards.

The communication-layer ablation the paper's §5.4 cost analysis begs
for: on the synthetic Reddit-like graph, sweep the exchange knobs and
report modelled push+pull bytes, modelled wire seconds, and peak
accuracy against the fp32 full-push single-shard baseline (the seed
configuration).  ``xred`` is the byte-reduction factor.

Expected shape of the results (acceptance targets):
  int8 + τ=0.05 delta → ≥3× fewer push+pull bytes, peak accuracy within
  1 point of fp32; 4-shard hashed transport → bit-identical accuracy
  with the traffic split across per-shard TransferLogs.

``sel=`` reports the delta-push selection fraction.  Over a short
actively-converging run every push row moves well above τ=5% per round
(measured: median relative L2 change ≈49% at round 3, ≈19% at round 6
and falling), so τ-savings appear only near convergence — the codec
carries the byte reduction early, the delta filter takes over late.
"""

from __future__ import annotations

import dataclasses

from repro.core import NetworkModel, Strategy

from .common import emit, graph_for, quick_mode, run_strategy

BASE = Strategy("E")          # full expansion, blocking pull/push

SWEEP = [
    ("fp32-full", {}),
    ("fp16-full", {"codec": "fp16"}),
    ("int8-full", {"codec": "int8"}),
    ("fp32-delta05", {"delta_threshold": 0.05}),
    ("int8-delta05", {"codec": "int8", "delta_threshold": 0.05}),
    ("int8-delta05-4shard", {"codec": "int8", "delta_threshold": 0.05,
                             "num_server_shards": 4}),
    ("fp32-4shard", {"num_server_shards": 4}),
]


def main() -> None:
    if quick_mode():
        from repro.graphs import make_graph
        rounds = 10
        graph, bs = make_graph("reddit", scale=0.2, seed=0), 64
    else:
        rounds = 20
        graph, bs = graph_for("reddit")

    results = {}
    for name, knobs in SWEEP:
        strat = dataclasses.replace(BASE, name=name, **knobs)
        tr, stats = run_strategy(graph, bs, strat, rounds=rounds)
        # wall_s: the modelled network time on the round critical path
        # (shards serve in parallel, so this FALLS with sharding);
        # link_s: total busy-seconds across all links (sum of per-shard
        # logs — RISES with shard count via per-shard RPC overheads).
        wall = sum(s.phases.pull + s.phases.dynamic_pull
                   + s.phases.push_transfer for s in stats)
        peak = max(s.accuracy for s in stats)
        results[name] = (tr.server.log.bytes, wall, tr.server.log.seconds,
                         peak, stats, tr)

    base_bytes = results["fp32-full"][0]
    base_peak = results["fp32-full"][3]
    for name, (nbytes, wall, link_s, peak, stats, tr) in results.items():
        xred = base_bytes / max(nbytes, 1)
        med = sorted(s.round_time for s in stats)[len(stats) // 2]
        trackers = [ex.delta for ex in tr.ex_clients
                    if ex is not None and ex.delta is not None]
        sel = "" if not trackers else " sel={:.2f}".format(
            sum(t.total_selected for t in trackers)
            / max(1, sum(t.total_rows for t in trackers)))
        emit(name, {"median_round_s": med},
             f"bytes={nbytes} wall_s={wall:.3f} link_s={link_s:.3f} "
             f"xred={xred:.2f} peak={peak:.4f} "
             f"dpeak={peak - base_peak:+.4f}{sel}")

    # per-shard traffic split of the hashed transport (parallel links)
    tr4 = results["int8-delta05-4shard"][5]
    split = " ".join(f"s{i}={lg.bytes}"
                     for i, lg in enumerate(tr4.server.shard_logs))
    emit("int8-delta05-4shard-split", {"median_round_s": 0.0}, split)

    # heterogeneous links: shard 0 on a 10× slower NIC dominates wall time
    strat = dataclasses.replace(BASE, name="hetero", codec="int8",
                                num_server_shards=4)
    nets = [NetworkModel(bandwidth_bytes_per_s=12.5e6)] + \
        [NetworkModel()] * 3
    tr, stats = run_strategy(graph, bs, strat, rounds=max(2, rounds // 5),
                             shard_nets=nets)
    wall = sum(s.phases.pull + s.phases.dynamic_pull
               + s.phases.push_transfer for s in stats)
    emit("int8-4shard-hetero-10x", {"median_round_s": sorted(
        s.round_time for s in stats)[len(stats) // 2]},
        f"wall_s={wall:.3f} link_s={tr.server.log.seconds:.3f}")


if __name__ == "__main__":
    main()
