"""Observability overhead bench: the <2% disabled-path guarantee.

The tracing/metrics instrumentation lives permanently in the hot paths
(trainer phases, exchange RPCs, coordinator, gnnserve), so the repo's
timing claims are only credible if the *disabled* instrumentation is
invisible next to a federated round.  This bench asserts that budget:

1. Microbenchmark the disabled-path primitives — a no-op span
   (``TRACE.span(...)`` with tracing off, including a representative
   args dict built at the call site) and a counter/histogram tick.
2. Run one measured federated round with tracing *enabled* and count
   the spans it records — the exact number of instrumentation call
   sites a round crosses (metrics tick at most as often).
3. Assert ``spans_per_round × (noop_span + metric_tick) cost < 2%`` of
   the disabled-path round's wall time.

This is a *direct* measurement of the overhead actually added (call
count × per-call cost), not a round-vs-round diff — round wall time
jitters by far more than the instrumentation costs, so a diff of two
noisy rounds could never resolve a sub-percent budget.

CSV rows: the usual ``name,us_per_call,derived``; exits non-zero if the
budget is violated (the CI observability job runs this informationally,
the assert is the contract).
"""

from __future__ import annotations

import time

from repro.core import FederatedGNNTrainer, default_strategies
from repro.graphs import make_graph
from repro.obsv.metrics import REGISTRY
from repro.obsv.trace import TRACE

from .common import emit

BUDGET = 0.02                     # <2% of a measured round
N_CALLS = 200_000                 # microbench loop size


def _noop_span_cost() -> float:
    """Seconds per disabled ``with TRACE.span(...)`` including a
    representative call-site args dict."""
    assert not TRACE.enabled
    t0 = time.perf_counter()
    for i in range(N_CALLS):
        with TRACE.span("bench.noop", args={"client": i}):
            pass
    return (time.perf_counter() - t0) / N_CALLS


def _metric_tick_cost() -> float:
    """Seconds per counter-inc + histogram-observe pair."""
    c = REGISTRY.counter("bench.obsv.ticks")
    h = REGISTRY.histogram("bench.obsv.tick_s")
    t0 = time.perf_counter()
    for _ in range(N_CALLS):
        c.inc()
        h.observe(1e-3)
    return (time.perf_counter() - t0) / N_CALLS


def main() -> None:
    g = make_graph("reddit", scale=0.05, seed=3)
    st = default_strategies()["E"]
    tr = FederatedGNNTrainer(g, 2, st, batch_size=64, seed=0)

    tr.train(1)                                   # warm the jit caches
    assert not TRACE.enabled
    t0 = time.perf_counter()
    tr.train(1)                                   # the measured round
    round_s = time.perf_counter() - t0

    # enabled round: count the spans one round records
    TRACE.enable()
    TRACE.clear()
    try:
        t0 = time.perf_counter()
        tr.train(1)
        round_enabled_s = time.perf_counter() - t0
        spans_per_round = len(TRACE.events)
        assert spans_per_round > 0, "instrumentation recorded nothing"
    finally:
        TRACE.disable()
        TRACE.clear()
        TRACE.set_context(round=None)

    span_cost = _noop_span_cost()
    tick_cost = _metric_tick_cost()
    # every span site charged a metric tick too — a strict upper bound
    # (most sites only trace)
    overhead_s = spans_per_round * (span_cost + tick_cost)
    frac = overhead_s / round_s

    emit("obsv/noop-span", {"median_round_s": span_cost},
         f"per_call_ns={span_cost * 1e9:.0f}")
    emit("obsv/metric-tick", {"median_round_s": tick_cost},
         f"per_call_ns={tick_cost * 1e9:.0f}")
    emit("obsv/round-overhead", {"median_round_s": round_s},
         f"spans_per_round={spans_per_round} "
         f"disabled_overhead_s={overhead_s:.6f} "
         f"disabled_overhead_frac={frac:.6f} "
         f"enabled_round_s={round_enabled_s:.3f}")
    print(f"# disabled instrumentation: {spans_per_round} sites/round × "
          f"{(span_cost + tick_cost) * 1e9:.0f} ns = "
          f"{overhead_s * 1e3:.3f} ms on a {round_s:.3f} s round "
          f"({frac * 100:.4f}%)", flush=True)
    assert frac < BUDGET, (
        f"disabled-path instrumentation costs {frac * 100:.3f}% of a "
        f"measured federated round (budget {BUDGET * 100:.0f}%)")


if __name__ == "__main__":
    main()
