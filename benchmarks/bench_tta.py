"""Fig. 6/8 (GraphConv) & Fig. 9 (SAGEConv): time-to-accuracy, peak
accuracy, and convergence curves for D/E/O/P/OP/OPP/OPG."""

from __future__ import annotations

import numpy as np

from repro.core import default_strategies, peak_accuracy

from .common import (target_margin, FULL, QUICK, emit, graph_for, quick_mode, run_strategy,
                     summarize, tta)


def run(*, conv: str = "graphconv", curves: bool = False):
    mode = QUICK if quick_mode() else FULL
    strategies = default_strategies()
    for gname in mode["graphs"]:
        g, bs = graph_for(gname)
        results = {}
        for sname, strat in strategies.items():
            _, stats = run_strategy(g, bs, strat, rounds=mode["rounds"],
                                    conv=conv)
            results[sname] = stats
        # target = within 1% of the min peak accuracy across strategies
        # that use embeddings (paper §5.2)
        peaks = [peak_accuracy(s) for s in results.values()]
        target = min(peaks) - target_margin()
        for sname, stats in results.items():
            s = summarize(stats)
            emit(f"tta/{conv}/{gname}/{sname}", s,
                 f"peak={s['peak_acc']:.4f};tta_s={tta(stats, target):.2f}")
            if curves:
                accs = ";".join(f"{st.accuracy:.4f}" for st in stats)
                print(f"curve/{conv}/{gname}/{sname},0,{accs}", flush=True)


def main():
    run(conv="graphconv", curves=True)
    if not quick_mode():
        run(conv="sageconv")


if __name__ == "__main__":
    main()
